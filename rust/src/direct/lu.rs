//! Gilbert–Peierls left-looking sparse LU with partial pivoting.
//!
//! The algorithm SuperLU builds on (non-supernodal form): per column, a
//! symbolic DFS over the current L graph finds the nonzero pattern, a
//! sparse triangular solve computes the numeric values, and the pivot is
//! the largest remaining entry.  Fill is whatever the elimination
//! produces — `factor_with_cap` aborts once the measured fill crosses a
//! byte budget, which is how the accelerator/direct backends surface the
//! paper's OOM rows *before* exhausting host memory.

use crate::error::{Error, Result};
use crate::sparse::Csr;

const UNPIVOTED: usize = usize::MAX;

/// Sparse LU factors: P A = L U (row pivoting only).
pub struct SparseLu {
    n: usize,
    /// L columns (excluding the implicit unit diagonal): (row, value).
    l_cols: Vec<Vec<(usize, f64)>>,
    /// U columns including the diagonal: (pivot position, value).
    u_cols: Vec<Vec<(usize, f64)>>,
    /// row -> pivot position.
    pinv: Vec<usize>,
    /// pivot position -> row.
    prow: Vec<usize>,
}

impl SparseLu {
    pub fn factor(a: &Csr) -> Result<Self> {
        Self::factor_with_cap(a, usize::MAX)
    }

    /// Factor, aborting with [`Error::OutOfMemory`] if the stored factor
    /// entries exceed `max_fill`.
    pub fn factor_with_cap(a: &Csr, max_fill: usize) -> Result<Self> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("lu needs square".into()));
        }
        let n = a.nrows;
        // CSC of A = CSR rows of A^T
        let at = a.transpose();

        let mut l_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut u_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut pinv = vec![UNPIVOTED; n];
        let mut prow = vec![0usize; n];

        let mut x = vec![0f64; n];
        let mut mark = vec![usize::MAX; n];
        let mut post: Vec<usize> = Vec::with_capacity(n);
        // explicit DFS stack: (node, child_cursor)
        let mut stack: Vec<(usize, usize)> = Vec::new();
        let mut fill = 0usize;

        for j in 0..n {
            // --- symbolic: reach of A[:,j] in the L graph, postorder ---
            post.clear();
            let (a_rows, a_vals) = at.row(j);
            for &r0 in a_rows {
                if mark[r0] == j {
                    continue;
                }
                stack.push((r0, 0));
                mark[r0] = j;
                while let Some(&mut (r, ref mut cur)) = stack.last_mut() {
                    let children: &[(usize, f64)] = if pinv[r] == UNPIVOTED {
                        &[]
                    } else {
                        &l_cols[pinv[r]]
                    };
                    let mut advanced = false;
                    while *cur < children.len() {
                        let child = children[*cur].0;
                        *cur += 1;
                        if mark[child] != j {
                            mark[child] = j;
                            stack.push((child, 0));
                            advanced = true;
                            break;
                        }
                    }
                    if !advanced {
                        post.push(r);
                        stack.pop();
                    }
                }
            }
            // --- numeric: sparse lower solve in reverse postorder ---
            for &r in &post {
                x[r] = 0.0;
            }
            for (&r, &v) in a_rows.iter().zip(a_vals) {
                x[r] = v;
            }
            for &r in post.iter().rev() {
                let k = pinv[r];
                if k == UNPIVOTED {
                    continue;
                }
                let xr = x[r];
                if xr != 0.0 {
                    for &(rr, lv) in &l_cols[k] {
                        x[rr] -= xr * lv;
                    }
                }
            }
            // --- pivot: largest |x| among unpivoted reach rows ---
            let mut piv_row = UNPIVOTED;
            let mut piv_abs = 0.0f64;
            for &r in &post {
                if pinv[r] == UNPIVOTED {
                    let a = x[r].abs();
                    if a > piv_abs {
                        piv_abs = a;
                        piv_row = r;
                    }
                }
            }
            if piv_row == UNPIVOTED || piv_abs == 0.0 || !piv_abs.is_finite() {
                return Err(Error::Breakdown {
                    at: j,
                    reason: "structurally or numerically singular".into(),
                });
            }
            let piv = x[piv_row];
            // --- gather U column (pivoted rows) and L column (rest) ---
            let mut ucol: Vec<(usize, f64)> = Vec::new();
            let mut lcol: Vec<(usize, f64)> = Vec::new();
            for &r in &post {
                let k = pinv[r];
                if k != UNPIVOTED {
                    if x[r] != 0.0 {
                        ucol.push((k, x[r]));
                    }
                } else if r != piv_row && x[r] != 0.0 {
                    lcol.push((r, x[r] / piv));
                }
            }
            ucol.push((j, piv)); // diagonal
            pinv[piv_row] = j;
            prow[j] = piv_row;
            fill += ucol.len() + lcol.len();
            if fill > max_fill {
                return Err(Error::OutOfMemory {
                    needed_bytes: (fill * 16) as u64,
                    budget_bytes: (max_fill * 16) as u64,
                });
            }
            u_cols.push(ucol);
            l_cols.push(lcol);
        }
        Ok(SparseLu {
            n,
            l_cols,
            u_cols,
            pinv,
            prow,
        })
    }

    /// Total stored factor entries (measured fill).
    pub fn fill(&self) -> usize {
        self.l_cols.iter().map(|c| c.len() + 1).sum::<usize>()
            + self.u_cols.iter().map(|c| c.len()).sum::<usize>()
    }

    pub fn bytes(&self) -> u64 {
        (self.fill() * 16 + 2 * self.n * 8) as u64
    }

    /// (sign, log|det|) of A: det(P A) = det(L) det(U) = prod(diag U),
    /// corrected by the pivot-permutation parity.
    pub fn slogdet(&self) -> (f64, f64) {
        let mut sign = 1.0f64;
        let mut logabs = 0.0f64;
        for j in 0..self.n {
            let mut d = 0.0;
            for &(i, v) in &self.u_cols[j] {
                if i == j {
                    d = v;
                }
            }
            if d == 0.0 {
                return (0.0, f64::NEG_INFINITY);
            }
            if d < 0.0 {
                sign = -sign;
            }
            logabs += d.abs().ln();
        }
        // permutation parity of pinv (row -> position): (-1)^(n - cycles)
        let mut seen = vec![false; self.n];
        for start in 0..self.n {
            if seen[start] {
                continue;
            }
            let mut len = 0usize;
            let mut cur = start;
            while !seen[cur] {
                seen[cur] = true;
                cur = self.pinv[cur];
                len += 1;
            }
            if len % 2 == 0 {
                sign = -sign;
            }
        }
        (sign, logabs)
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.n {
            return Err(crate::error::Error::InvalidProblem(format!(
                "rhs length {} != n {}",
                b.len(),
                self.n
            )));
        }
        // forward: L y = P b, working in original-row space
        let mut work = b.to_vec();
        let mut y = vec![0f64; self.n];
        for k in 0..self.n {
            let r = self.prow[k];
            let yk = work[r];
            y[k] = yk;
            if yk != 0.0 {
                for &(rr, lv) in &self.l_cols[k] {
                    work[rr] -= yk * lv;
                }
            }
        }
        // backward: U x = y (columns right-to-left)
        let mut x = y;
        for j in (0..self.n).rev() {
            let mut diag = 0.0;
            for &(i, v) in &self.u_cols[j] {
                if i == j {
                    diag = v;
                }
            }
            if diag == 0.0 {
                return Err(Error::Breakdown {
                    at: j,
                    reason: "zero U diagonal".into(),
                });
            }
            let xj = x[j] / diag;
            x[j] = xj;
            if xj != 0.0 {
                for &(i, v) in &self.u_cols[j] {
                    if i < j {
                        x[i] -= v * xj;
                    }
                }
            }
        }
        Ok(x)
    }

    /// Solve A^T x = b (the adjoint solve reuses the same factorization,
    /// paper §3.2.3: "reusing the same backend and, where applicable, the
    /// same factorization").  From P A = L U: A^T = U^T L^T P.
    pub fn solve_t(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.n {
            return Err(crate::error::Error::InvalidProblem(format!(
                "rhs length {} != n {}",
                b.len(),
                self.n
            )));
        }
        // forward: U^T z = b (columns left-to-right; U^T is lower)
        let mut z = b.to_vec();
        for j in 0..self.n {
            let mut diag = 0.0;
            let mut s = z[j];
            for &(i, v) in &self.u_cols[j] {
                if i == j {
                    diag = v;
                } else {
                    s -= v * z_at(&z, i);
                }
            }
            if diag == 0.0 {
                return Err(Error::Breakdown {
                    at: j,
                    reason: "zero U diagonal".into(),
                });
            }
            z[j] = s / diag;
        }
        // backward: L^T w = z (unit diagonal; columns right-to-left)
        let mut w = z;
        for k in (0..self.n).rev() {
            let mut s = w[k];
            for &(rr, lv) in &self.l_cols[k] {
                // L[rr', k] with rr original row; its pivot position is pinv[rr]
                s -= lv * w_at(&w, self.pinv[rr]);
            }
            w[k] = s;
        }
        // x = P^T w: x[row] = w[pinv[row]]
        let mut x = vec![0f64; self.n];
        for r in 0..self.n {
            x[r] = w[self.pinv[r]];
        }
        Ok(x)
    }
}

#[inline]
fn z_at(z: &[f64], i: usize) -> f64 {
    z[i]
}

#[inline]
fn w_at(w: &[f64], i: usize) -> f64 {
    w[i]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::{random_nonsymmetric, random_spd};
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn solves_nonsymmetric() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 80, 5);
        let f = SparseLu::factor(&a).unwrap();
        let b = rng.normal_vec(80);
        let x = f.solve(&b).unwrap();
        assert!(util::rel_l2(&a.matvec(&x), &b) < 1e-11);
    }

    #[test]
    fn solves_poisson_to_machine_precision() {
        let g = 14;
        let sys = poisson2d(g, None);
        let f = SparseLu::factor(&sys.matrix).unwrap();
        let mut rng = Prng::new(2);
        let b = rng.normal_vec(g * g);
        let x = f.solve(&b).unwrap();
        assert!(util::rel_l2(&sys.matrix.matvec(&x), &b) < 1e-12);
    }

    #[test]
    fn transpose_solve() {
        let mut rng = Prng::new(3);
        let a = random_nonsymmetric(&mut rng, 50, 4);
        let f = SparseLu::factor(&a).unwrap();
        let b = rng.normal_vec(50);
        let x = f.solve_t(&b).unwrap();
        let mut atx = vec![0.0; 50];
        a.spmv_t(&x, &mut atx);
        assert!(util::rel_l2(&atx, &b) < 1e-11);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        use crate::sparse::Coo;
        // [[0, 1], [1, 0]] needs a row swap
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        let a = coo.to_csr();
        let f = SparseLu::factor(&a).unwrap();
        let x = f.solve(&[3.0, 7.0]).unwrap();
        assert!((x[0] - 7.0).abs() < 1e-14);
        assert!((x[1] - 3.0).abs() < 1e-14);
    }

    #[test]
    fn singular_matrix_breaks_down() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 1.0);
        // row/col 2 empty -> structurally singular
        let a = coo.to_csr();
        assert!(matches!(
            SparseLu::factor(&a),
            Err(Error::Breakdown { .. })
        ));
    }

    #[test]
    fn fill_cap_aborts_with_oom() {
        let g = 12;
        let sys = poisson2d(g, None);
        match SparseLu::factor_with_cap(&sys.matrix, 50) {
            Err(Error::OutOfMemory { .. }) => {}
            other => panic!("expected OOM, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn spd_matches_cholesky() {
        let mut rng = Prng::new(4);
        let a = random_spd(&mut rng, 40, 3, 1.5);
        let b = rng.normal_vec(40);
        let xl = SparseLu::factor(&a).unwrap().solve(&b).unwrap();
        let xc = super::super::EnvelopeCholesky::factor(&a).unwrap().solve(&b);
        assert!(util::max_abs_diff(&xl, &xc) < 1e-8);
    }

    #[test]
    fn solve_and_solve_t_agree_on_symmetric() {
        let g = 8;
        let sys = poisson2d(g, None);
        let f = SparseLu::factor(&sys.matrix).unwrap();
        let mut rng = Prng::new(5);
        let b = rng.normal_vec(g * g);
        let x = f.solve(&b).unwrap();
        let xt = f.solve_t(&b).unwrap();
        assert!(util::max_abs_diff(&x, &xt) < 1e-9);
    }
}
