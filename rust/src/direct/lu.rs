//! Gilbert–Peierls left-looking sparse LU with partial pivoting.
//!
//! The algorithm SuperLU builds on (non-supernodal form): per column, a
//! symbolic DFS over the current L graph finds the nonzero pattern, a
//! sparse triangular solve computes the numeric values, and the pivot is
//! the largest remaining entry.  Fill is whatever the elimination
//! produces — `factor_with_cap` aborts once the measured fill crosses a
//! byte budget, which is how the accelerator/direct backends surface the
//! paper's OOM rows *before* exhausting host memory.

use super::supernodal::{SupernodalOpts, SN_MAX_WIDTH};
use crate::error::{Error, Result};
use crate::metrics::{names as mn, Registry};
use crate::sparse::align::AlignedVec;
use crate::sparse::kernels::panel_sub_scaled;
use crate::sparse::Csr;
use crate::trace::{self, names as tn};

const UNPIVOTED: usize = usize::MAX;

/// The reusable half of a Gilbert–Peierls factorization: pivot order
/// and per-column elimination reach, recorded during a first
/// ("recording") factorization and replayed by [`SparseLu::refactor`]
/// when only the numeric values change (fixed sparsity pattern).
///
/// Partial pivoting makes a purely pattern-based symbolic phase
/// impossible (pivots depend on values), so — like KLU/SuperLU
/// refactorization — the first factorization decides the pivots and
/// this struct freezes them.  The recorded reach is computed over the
/// *structural* (unpruned) L pattern, so it stays a valid superset for
/// any values bound to the same pattern.  A pivot that becomes zero
/// under new values surfaces as [`Error::Breakdown`]; callers then fall
/// back to a fresh pivoting factorization.
pub struct LuSymbolic {
    n: usize,
    /// Per-column postorder reach of A[:,j] in the recorded L graph.
    post: Vec<Vec<usize>>,
    /// row -> pivot position (complete).
    pinv: Vec<usize>,
    /// pivot position -> row.
    prow: Vec<usize>,
    /// Stored factor entries of the recording factorization.
    fill: usize,
}

impl LuSymbolic {
    pub fn n(&self) -> usize {
        self.n
    }

    /// Factor entries the numeric phase will allocate.
    pub fn fill(&self) -> usize {
        self.fill
    }

    /// Bytes held by the symbolic structure itself.
    pub fn bytes(&self) -> u64 {
        let post_total: usize = self.post.iter().map(|p| p.len()).sum();
        ((post_total + 2 * self.n) * 8) as u64
    }
}

/// Panel partition of a recorded pivot sequence: LU's analogue of the
/// Cholesky supernode partition, computed over [`LuSymbolic`]'s
/// recorded reach lists instead of an elimination tree (partial
/// pivoting has no pattern-only etree).  Consecutive pivot columns
/// merge into a panel while the union reach keeps the dense working
/// block within the relaxed-amalgamation bound; per panel the union
/// reach is stored sorted by pivot position ascending, which is a valid
/// topological order for the blocked replay
/// ([`SparseLu::refactor_blocked`]).
///
/// Pattern-deterministic: depends only on the recording's structure
/// and the options, so cold and warm paths always agree on engagement.
pub struct LuPanels {
    /// Panel `p` covers pivot columns `sn_ptr[p]..sn_ptr[p+1]`.
    sn_ptr: Vec<usize>,
    /// Concatenated union reaches, sorted by `pinv` ascending.
    rows: Vec<usize>,
    row_ptr: Vec<usize>,
    /// Widest panel (columns).
    max_width: usize,
    /// Whether the blocked replay is worth running for this recording.
    engaged: bool,
}

impl LuPanels {
    /// Plan panels over a recorded factorization.  Growth heuristic:
    /// extend the panel while `|union reach| * width` stays within
    /// `(1 + relax)` of the summed per-column reach sizes — the same
    /// explicit-zero bound the Cholesky amalgamation uses.
    // rsla-lint: allow_item(L1, panel bounds come from the recorded symbolic pattern; pinv entries are pivot rows < n)
    pub fn plan(sym: &LuSymbolic, opts: &SupernodalOpts) -> LuPanels {
        let n = sym.n;
        let max_width = opts.max_width.clamp(1, SN_MAX_WIDTH);
        let mut sn_ptr = vec![0usize];
        let mut rows: Vec<usize> = Vec::new();
        let mut row_ptr = vec![0usize];
        let mut mark = vec![usize::MAX; n];
        let mut cur_rows: Vec<usize> = Vec::new();
        let mut added: Vec<usize> = Vec::new();
        let mut max_w = 0usize;
        let mut j = 0usize;
        while j < n {
            let stamp = j + 1; // unique per panel: j strictly increases
            cur_rows.clear();
            for &r in &sym.post[j] {
                if mark[r] != stamp {
                    mark[r] = stamp;
                    cur_rows.push(r);
                }
            }
            let mut nz = sym.post[j].len();
            let mut hi = j + 1;
            while hi < n && hi - j < max_width {
                added.clear();
                for &r in &sym.post[hi] {
                    if mark[r] != stamp {
                        mark[r] = stamp;
                        added.push(r);
                    }
                }
                let cand_rows = cur_rows.len() + added.len();
                let cand_nz = nz + sym.post[hi].len();
                if (cand_rows * (hi - j + 1)) as f64 > (1.0 + opts.relax) * cand_nz as f64 {
                    for &r in &added {
                        mark[r] = usize::MAX;
                    }
                    break;
                }
                cur_rows.extend_from_slice(&added);
                nz = cand_nz;
                hi += 1;
            }
            cur_rows.sort_unstable_by_key(|&r| sym.pinv[r]);
            max_w = max_w.max(hi - j);
            rows.extend_from_slice(&cur_rows);
            row_ptr.push(rows.len());
            sn_ptr.push(hi);
            j = hi;
        }
        let engaged = max_w >= opts.engage_min_width.max(1) && n > 0;
        LuPanels {
            sn_ptr,
            rows,
            row_ptr,
            max_width: max_w,
            engaged,
        }
    }

    /// Number of panels.
    pub fn npanels(&self) -> usize {
        self.sn_ptr.len() - 1
    }

    /// Widest panel (columns).
    pub fn max_panel_width(&self) -> usize {
        self.max_width
    }

    /// Whether the blocked replay should be used for this recording.
    pub fn engaged(&self) -> bool {
        self.engaged
    }

    /// Bytes held by the plan itself.
    pub fn bytes(&self) -> u64 {
        ((self.sn_ptr.len() + self.rows.len() + self.row_ptr.len()) * 8) as u64
    }
}

/// The per-column NUMERIC kernel shared by [`SparseLu::factor_recording`]
/// and [`SparseLu::refactor`]: clear the workspace over the reach,
/// scatter A's column, and run the sparse lower solve in reverse
/// postorder against the already-built L columns.
///
/// The bitwise-replay guarantee (and the cache's property test) depends
/// on the recording and replay paths executing the IDENTICAL
/// floating-point schedule — sharing this one function is what enforces
/// that, by code rather than by comment.  `pinv[r] >= j` means "row r
/// not yet pivoted at step j" in both callers: during recording,
/// unpivoted rows hold `UNPIVOTED` (= usize::MAX); during replay the
/// complete pivot map is used and later-pivoted rows compare `>= j`.
// rsla-lint: no_alloc
#[inline]
// rsla-lint: allow_item(L1, reach and pivot rows were bounds-validated when the pattern was recorded)
fn lu_column_numeric(
    post: &[usize],
    a_rows: &[usize],
    a_vals: &[f64],
    pinv: &[usize],
    l_cols: &[Vec<(usize, f64)>],
    j: usize,
    x: &mut [f64],
) {
    for &r in post {
        x[r] = 0.0;
    }
    for (&r, &v) in a_rows.iter().zip(a_vals) {
        x[r] = v;
    }
    for &r in post.iter().rev() {
        let k = pinv[r];
        if k >= j {
            continue; // not yet pivoted at step j
        }
        let xr = x[r];
        if xr != 0.0 {
            for &(rr, lv) in &l_cols[k] {
                x[rr] -= xr * lv;
            }
        }
    }
}

/// The structure-complete column gather shared by the recording and
/// replay paths (no zero pruning; same FP schedule — see
/// [`lu_column_numeric`]).  Entries with `pinv[r] < j` belong to U;
/// the rest (minus the pivot row itself) form L, scaled by the pivot.
#[inline]
// rsla-lint: allow_item(L1, gather follows the recorded post order; all indices < n by construction)
fn lu_column_gather(
    post: &[usize],
    pinv: &[usize],
    j: usize,
    piv_row: usize,
    piv: f64,
    x: &[f64],
) -> (Vec<(usize, f64)>, Vec<(usize, f64)>) {
    let mut ucol: Vec<(usize, f64)> = Vec::new();
    let mut lcol: Vec<(usize, f64)> = Vec::new();
    for &r in post {
        let k = pinv[r];
        if k < j {
            ucol.push((k, x[r]));
        } else if r != piv_row {
            lcol.push((r, x[r] / piv));
        }
    }
    ucol.push((j, piv)); // diagonal
    (ucol, lcol)
}

/// Shared blocked-replay numeric body (cold and warm both come through
/// here — the bitwise refactor-vs-cold pin on the blocked path).
/// Compiled twice, generic and under `target_feature(avx2)`, dispatched
/// once per factorization by [`lu_blocked_numeric`].
// rsla-lint: allow_item(L1, panel kernel over offsets the plan sized; reach containment and pinv-ordering invariants are established by the recording DFS and LuPanels::plan)
#[inline(always)]
fn lu_blocked_body(
    sym: &LuSymbolic,
    plan: &LuPanels,
    a: &Csr,
    max_fill: usize,
) -> Result<(SparseLu, u64)> {
    let n = sym.n;
    let at = a.transpose();
    let mut l_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
    let mut u_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
    let mut pos = vec![0usize; n];
    let mut max_block = 0usize;
    for p in 0..plan.npanels() {
        let w = plan.sn_ptr[p + 1] - plan.sn_ptr[p];
        let m = plan.row_ptr[p + 1] - plan.row_ptr[p];
        max_block = max_block.max(m * w);
    }
    let mut wblock = AlignedVec::<f64>::zeroed(max_block);
    let mut fill = 0usize;
    let mut flops = 0u64;
    for p in 0..plan.npanels() {
        let lo = plan.sn_ptr[p];
        let hi = plan.sn_ptr[p + 1];
        let w = hi - lo;
        let r0 = plan.row_ptr[p];
        let m = plan.row_ptr[p + 1] - r0;
        let prows = &plan.rows[r0..r0 + m];
        for (k, &r) in prows.iter().enumerate() {
            pos[r] = k;
        }
        let wb = &mut wblock[..m * w];
        for v in wb.iter_mut() {
            *v = 0.0;
        }
        // scatter A's panel columns (the reach contains the A pattern)
        for jj in lo..hi {
            let (a_rows, a_vals) = at.row(jj);
            for (&r, &v) in a_rows.iter().zip(a_vals) {
                wb[pos[r] * w + (jj - lo)] = v;
            }
        }
        // external updates: already-factored pivots inside the union
        // reach sit at the head (pinv ascending), and every L row they
        // touch has a larger pinv — strictly below in the block.
        let mut n_ext = 0usize;
        while n_ext < m && sym.pinv[prows[n_ext]] < lo {
            n_ext += 1;
        }
        for k in 0..n_ext {
            let c = sym.pinv[prows[k]];
            let (head, tail) = wb.split_at_mut((k + 1) * w);
            let urow = &head[k * w..];
            if urow.iter().all(|&v| v == 0.0) {
                continue;
            }
            for &(rr, lv) in &l_cols[c] {
                let t = pos[rr];
                let dst = &mut tail[(t - k - 1) * w..(t - k) * w];
                panel_sub_scaled(dst, lv, urow);
            }
            flops += (2 * w * l_cols[c].len()) as u64;
        }
        // in-panel right-looking factorization on the recorded pivots
        let mut pivrow = [0.0f64; SN_MAX_WIDTH];
        for cc in 0..w {
            let j = lo + cc;
            let piv_k = n_ext + cc;
            debug_assert_eq!(
                prows[piv_k],
                sym.prow[j],
                "pinv-sorted reach places panel pivots consecutively"
            );
            let piv = wb[piv_k * w + cc];
            // KLU-style stability guard over the recorded reach — same
            // contract as SparseLu::refactor (read-only on the block).
            let mut colmax = 0.0f64;
            for &r in &sym.post[j] {
                let ax = wb[pos[r] * w + cc].abs();
                if ax > colmax {
                    colmax = ax;
                }
            }
            if piv == 0.0 || !piv.is_finite() || piv.abs() < 1e-12 * colmax {
                return Err(Error::Breakdown {
                    at: j,
                    reason:
                        "recorded pivot vanished or degraded under new values (blocked refactor)"
                            .into(),
                });
            }
            for k in piv_k + 1..m {
                wb[k * w + cc] /= piv;
            }
            if cc + 1 < w {
                let tail_w = w - cc - 1;
                pivrow[..tail_w].copy_from_slice(&wb[piv_k * w + cc + 1..piv_k * w + w]);
                let prow_vals = &pivrow[..tail_w];
                for k in piv_k + 1..m {
                    let lv = wb[k * w + cc];
                    if lv != 0.0 {
                        let dst = &mut wb[k * w + cc + 1..k * w + w];
                        panel_sub_scaled(dst, lv, prow_vals);
                    }
                }
                flops += (2 * (m - piv_k - 1) * tail_w) as u64;
            }
        }
        // gather each column in recorded reach order: identical
        // structure and storage to lu_column_gather's output (L values
        // were divided in place; U values and the diagonal are raw).
        for cc in 0..w {
            let j = lo + cc;
            let piv_row = sym.prow[j];
            let mut ucol: Vec<(usize, f64)> = Vec::with_capacity(sym.post[j].len() + 1);
            let mut lcol: Vec<(usize, f64)> = Vec::with_capacity(sym.post[j].len());
            for &r in &sym.post[j] {
                let k = sym.pinv[r];
                if k < j {
                    ucol.push((k, wb[pos[r] * w + cc]));
                } else if r != piv_row {
                    lcol.push((r, wb[pos[r] * w + cc]));
                }
            }
            ucol.push((j, wb[pos[piv_row] * w + cc]));
            fill += ucol.len() + lcol.len();
            if fill > max_fill {
                return Err(Error::OutOfMemory {
                    needed_bytes: (fill * 16) as u64,
                    budget_bytes: (max_fill * 16) as u64,
                });
            }
            u_cols.push(ucol);
            l_cols.push(lcol);
        }
    }
    Ok((
        SparseLu {
            n,
            l_cols,
            u_cols,
            pinv: sym.pinv.clone(),
            prow: sym.prow.clone(),
        },
        flops,
    ))
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn lu_blocked_avx2(
    sym: &LuSymbolic,
    plan: &LuPanels,
    a: &Csr,
    max_fill: usize,
) -> Result<(SparseLu, u64)> {
    lu_blocked_body(sym, plan, a, max_fill)
}

fn lu_blocked_numeric(
    sym: &LuSymbolic,
    plan: &LuPanels,
    a: &Csr,
    max_fill: usize,
) -> Result<(SparseLu, u64)> {
    #[cfg(target_arch = "x86_64")]
    if crate::sparse::kernels::avx2_available() {
        // SAFETY: gated on runtime AVX2 detection, constant within a
        // process — cold and warm runs take the same schedule.
        return unsafe { lu_blocked_avx2(sym, plan, a, max_fill) };
    }
    lu_blocked_body(sym, plan, a, max_fill)
}

/// Sparse LU factors: P A = L U (row pivoting only).
pub struct SparseLu {
    n: usize,
    /// L columns (excluding the implicit unit diagonal): (row, value).
    l_cols: Vec<Vec<(usize, f64)>>,
    /// U columns including the diagonal: (pivot position, value).
    u_cols: Vec<Vec<(usize, f64)>>,
    /// row -> pivot position.
    pinv: Vec<usize>,
    /// pivot position -> row.
    prow: Vec<usize>,
}

impl SparseLu {
    pub fn factor(a: &Csr) -> Result<Self> {
        Self::factor_with_cap(a, usize::MAX)
    }

    /// Factor, aborting with [`Error::OutOfMemory`] if the stored factor
    /// entries exceed `max_fill`.
    // rsla-lint: allow_item(L1, workspace arrays are sized to n at entry and reach indices stay < n)
    pub fn factor_with_cap(a: &Csr, max_fill: usize) -> Result<Self> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("lu needs square".into()));
        }
        let n = a.nrows;
        // CSC of A = CSR rows of A^T
        let at = a.transpose();

        let mut l_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut u_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut pinv = vec![UNPIVOTED; n];
        let mut prow = vec![0usize; n];

        let mut x = vec![0f64; n];
        let mut mark = vec![usize::MAX; n];
        let mut post: Vec<usize> = Vec::with_capacity(n);
        // explicit DFS stack: (node, child_cursor)
        let mut stack: Vec<(usize, usize)> = Vec::new();
        let mut fill = 0usize;

        for j in 0..n {
            // --- symbolic: reach of A[:,j] in the L graph, postorder ---
            post.clear();
            let (a_rows, a_vals) = at.row(j);
            for &r0 in a_rows {
                if mark[r0] == j {
                    continue;
                }
                stack.push((r0, 0));
                mark[r0] = j;
                while let Some(&mut (r, ref mut cur)) = stack.last_mut() {
                    let children: &[(usize, f64)] = if pinv[r] == UNPIVOTED {
                        &[]
                    } else {
                        &l_cols[pinv[r]]
                    };
                    let mut advanced = false;
                    while *cur < children.len() {
                        let child = children[*cur].0;
                        *cur += 1;
                        if mark[child] != j {
                            mark[child] = j;
                            stack.push((child, 0));
                            advanced = true;
                            break;
                        }
                    }
                    if !advanced {
                        post.push(r);
                        stack.pop();
                    }
                }
            }
            // --- numeric: sparse lower solve in reverse postorder ---
            for &r in &post {
                x[r] = 0.0;
            }
            for (&r, &v) in a_rows.iter().zip(a_vals) {
                x[r] = v;
            }
            for &r in post.iter().rev() {
                let k = pinv[r];
                if k == UNPIVOTED {
                    continue;
                }
                let xr = x[r];
                if xr != 0.0 {
                    for &(rr, lv) in &l_cols[k] {
                        x[rr] -= xr * lv;
                    }
                }
            }
            // --- pivot: largest |x| among unpivoted reach rows ---
            let mut piv_row = UNPIVOTED;
            let mut piv_abs = 0.0f64;
            for &r in &post {
                if pinv[r] == UNPIVOTED {
                    let a = x[r].abs();
                    if a > piv_abs {
                        piv_abs = a;
                        piv_row = r;
                    }
                }
            }
            if piv_row == UNPIVOTED || piv_abs == 0.0 || !piv_abs.is_finite() {
                return Err(Error::Breakdown {
                    at: j,
                    reason: "structurally or numerically singular".into(),
                });
            }
            let piv = x[piv_row];
            // --- gather U column (pivoted rows) and L column (rest) ---
            let mut ucol: Vec<(usize, f64)> = Vec::new();
            let mut lcol: Vec<(usize, f64)> = Vec::new();
            for &r in &post {
                let k = pinv[r];
                if k != UNPIVOTED {
                    if x[r] != 0.0 {
                        ucol.push((k, x[r]));
                    }
                } else if r != piv_row && x[r] != 0.0 {
                    lcol.push((r, x[r] / piv));
                }
            }
            ucol.push((j, piv)); // diagonal
            pinv[piv_row] = j;
            prow[j] = piv_row;
            fill += ucol.len() + lcol.len();
            if fill > max_fill {
                return Err(Error::OutOfMemory {
                    needed_bytes: (fill * 16) as u64,
                    budget_bytes: (max_fill * 16) as u64,
                });
            }
            u_cols.push(ucol);
            l_cols.push(lcol);
        }
        Ok(SparseLu {
            n,
            l_cols,
            u_cols,
            pinv,
            prow,
        })
    }

    /// Factor like [`SparseLu::factor_with_cap`], additionally recording
    /// the symbolic structure (pivot order + elimination reach) so later
    /// values on the same pattern can be refactored numerically via
    /// [`SparseLu::refactor`] without redoing the symbolic DFS or the
    /// pivot search.
    ///
    /// Unlike the plain path, the recorded factorization stores
    /// structurally-complete columns (no dropping of exact-zero
    /// entries): the reach must be closed under the *pattern*, not under
    /// one particular value assignment, for the replay to be sound.
    ///
    /// The per-column numeric work (clear/scatter/lower-solve and the
    /// gather) is the SAME code [`SparseLu::refactor`] replays —
    /// [`lu_column_numeric`] / [`lu_column_gather`] — so the two paths
    /// stay in floating-point lockstep by construction.
    // rsla-lint: allow_item(L1, workspace arrays are sized to n at entry and reach indices stay < n)
    pub fn factor_recording(a: &Csr, max_fill: usize) -> Result<(Self, LuSymbolic)> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("lu needs square".into()));
        }
        let n = a.nrows;
        let at = a.transpose();

        let mut l_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut u_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut pinv = vec![UNPIVOTED; n];
        let mut prow = vec![0usize; n];
        let mut post_lists: Vec<Vec<usize>> = Vec::with_capacity(n);

        let mut x = vec![0f64; n];
        let mut mark = vec![usize::MAX; n];
        let mut post: Vec<usize> = Vec::with_capacity(n);
        let mut stack: Vec<(usize, usize)> = Vec::new();
        let mut fill = 0usize;

        for j in 0..n {
            // --- symbolic: reach of A[:,j] in the (unpruned) L graph ---
            post.clear();
            let (a_rows, a_vals) = at.row(j);
            for &r0 in a_rows {
                if mark[r0] == j {
                    continue;
                }
                stack.push((r0, 0));
                mark[r0] = j;
                while let Some(&mut (r, ref mut cur)) = stack.last_mut() {
                    let children: &[(usize, f64)] = if pinv[r] == UNPIVOTED {
                        &[]
                    } else {
                        &l_cols[pinv[r]]
                    };
                    let mut advanced = false;
                    while *cur < children.len() {
                        let child = children[*cur].0;
                        *cur += 1;
                        if mark[child] != j {
                            mark[child] = j;
                            stack.push((child, 0));
                            advanced = true;
                            break;
                        }
                    }
                    if !advanced {
                        post.push(r);
                        stack.pop();
                    }
                }
            }
            // --- numeric: the SHARED per-column kernel ---
            lu_column_numeric(&post, a_rows, a_vals, &pinv, &l_cols, j, &mut x);
            // --- pivot: largest |x| among unpivoted reach rows ---
            let mut piv_row = UNPIVOTED;
            let mut piv_abs = 0.0f64;
            for &r in &post {
                if pinv[r] == UNPIVOTED {
                    let a = x[r].abs();
                    if a > piv_abs {
                        piv_abs = a;
                        piv_row = r;
                    }
                }
            }
            if piv_row == UNPIVOTED || piv_abs == 0.0 || !piv_abs.is_finite() {
                return Err(Error::Breakdown {
                    at: j,
                    reason: "structurally or numerically singular".into(),
                });
            }
            let piv = x[piv_row];
            // --- gather, structure-complete (SHARED kernel) ---
            let (ucol, lcol) = lu_column_gather(&post, &pinv, j, piv_row, piv, &x);
            pinv[piv_row] = j;
            prow[j] = piv_row;
            fill += ucol.len() + lcol.len();
            if fill > max_fill {
                return Err(Error::OutOfMemory {
                    needed_bytes: (fill * 16) as u64,
                    budget_bytes: (max_fill * 16) as u64,
                });
            }
            u_cols.push(ucol);
            l_cols.push(lcol);
            post_lists.push(post.clone());
        }
        let symbolic = LuSymbolic {
            n,
            post: post_lists,
            pinv: pinv.clone(),
            prow: prow.clone(),
            fill,
        };
        Ok((
            SparseLu {
                n,
                l_cols,
                u_cols,
                pinv,
                prow,
            },
            symbolic,
        ))
    }

    /// Numeric-only refactorization: replay a recorded pivot order and
    /// elimination reach against new values bound to the *same* sparsity
    /// pattern.  Skips the symbolic DFS and the pivot search entirely;
    /// with unchanged values the result is bit-identical to the
    /// recording factorization.
    ///
    /// The per-column clear/scatter/lower-solve and gather are the SAME
    /// functions the recording path ran ([`lu_column_numeric`] /
    /// [`lu_column_gather`]), so floating-point lockstep — which the
    /// bitwise-replay guarantee and the cache's property test depend on
    /// — is enforced by code, not by comment.
    ///
    /// Returns [`Error::Breakdown`] when a recorded pivot becomes zero
    /// (or non-finite) under the new values — the caller should then
    /// fall back to a fresh [`SparseLu::factor_recording`].
    // rsla-lint: allow_item(L1, replayed pivot order was recorded on an identically-shaped matrix)
    pub fn refactor(sym: &LuSymbolic, a: &Csr, max_fill: usize) -> Result<Self> {
        if a.nrows != a.ncols || a.nrows != sym.n {
            return Err(Error::InvalidProblem(format!(
                "refactor shape mismatch: matrix {}x{}, symbolic n {}",
                a.nrows, a.ncols, sym.n
            )));
        }
        let n = sym.n;
        let at = a.transpose();

        let mut l_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut u_cols: Vec<Vec<(usize, f64)>> = Vec::with_capacity(n);
        let mut x = vec![0f64; n];
        let mut fill = 0usize;

        for j in 0..n {
            let post = &sym.post[j];
            let (a_rows, a_vals) = at.row(j);
            // --- numeric: the SHARED per-column kernel ---
            lu_column_numeric(post, a_rows, a_vals, &sym.pinv, &l_cols, j, &mut x);
            let piv_row = sym.prow[j];
            let piv = x[piv_row];
            // KLU-style stability guard: a recorded pivot that became
            // tiny RELATIVE to its column would replay with unbounded
            // element growth and hand back a silently inaccurate
            // factorization.  Bail out so the caller re-pivots cold.
            // (Read-only on x: does not perturb the bitwise replay.)
            let mut colmax = 0.0f64;
            for &r in post {
                let ax = x[r].abs();
                if ax > colmax {
                    colmax = ax;
                }
            }
            if piv == 0.0 || !piv.is_finite() || piv.abs() < 1e-12 * colmax {
                return Err(Error::Breakdown {
                    at: j,
                    reason: "recorded pivot vanished or degraded under new values (refactor aborted)"
                        .into(),
                });
            }
            // --- gather (SHARED kernel) ---
            let (ucol, lcol) = lu_column_gather(post, &sym.pinv, j, piv_row, piv, &x);
            fill += ucol.len() + lcol.len();
            if fill > max_fill {
                return Err(Error::OutOfMemory {
                    needed_bytes: (fill * 16) as u64,
                    budget_bytes: (max_fill * 16) as u64,
                });
            }
            u_cols.push(ucol);
            l_cols.push(lcol);
        }
        Ok(SparseLu {
            n,
            l_cols,
            u_cols,
            pinv: sym.pinv.clone(),
            prow: sym.prow.clone(),
        })
    }

    /// Blocked (panel) numeric replay of a recorded factorization: the
    /// supernodal analogue of [`SparseLu::refactor`].  Per panel, the
    /// union reach is gathered into one dense row-major working block,
    /// already-factored external pivots apply as dense rank-1 row
    /// updates ([`panel_sub_scaled`]), the panel's own pivot columns
    /// factor right-looking inside the block, and each column gathers
    /// back in its recorded reach order — so the produced factor has
    /// IDENTICAL structure and storage layout to the column replay's
    /// (`method()` and every downstream consumer are unchanged).
    ///
    /// Determinism: the schedule depends only on the recording, the
    /// plan, and the values; cold-blocked and warm-blocked runs are
    /// bitwise identical (the cache's refactor-vs-cold pin on the
    /// blocked path).  Numerical agreement with the column replay is
    /// reassociation-level, pinned at tolerance by
    /// `tests/supernodal_parity.rs`.
    pub fn refactor_blocked(
        sym: &LuSymbolic,
        plan: &LuPanels,
        a: &Csr,
        max_fill: usize,
    ) -> Result<Self> {
        if a.nrows != a.ncols || a.nrows != sym.n {
            return Err(Error::InvalidProblem(format!(
                "refactor shape mismatch: matrix {}x{}, symbolic n {}",
                a.nrows, a.ncols, sym.n
            )));
        }
        if plan.sn_ptr.last() != Some(&sym.n) || plan.row_ptr.len() != plan.sn_ptr.len() {
            return Err(Error::InvalidProblem(
                "panel plan does not cover the recorded factorization".into(),
            ));
        }
        let _span = trace::span_arg(tn::DIRECT_SUPERNODAL_NUMERIC, plan.npanels() as u64);
        let out = lu_blocked_numeric(sym, plan, a, max_fill)?;
        let reg = Registry::global();
        reg.incr(mn::FACTOR_SUPERNODE_COUNT, plan.npanels() as u64);
        reg.incr(mn::FACTOR_SUPERNODE_MAX_COLS, plan.max_panel_width() as u64);
        reg.incr(mn::FACTOR_PANEL_FLOPS, out.1);
        Ok(out.0)
    }

    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Total stored factor entries (measured fill).
    pub fn fill(&self) -> usize {
        self.l_cols.iter().map(|c| c.len() + 1).sum::<usize>()
            + self.u_cols.iter().map(|c| c.len()).sum::<usize>()
    }

    pub fn bytes(&self) -> u64 {
        (self.fill() * 16 + 2 * self.n * 8) as u64
    }

    /// (sign, log|det|) of A: det(P A) = det(L) det(U) = prod(diag U),
    /// corrected by the pivot-permutation parity.
    // rsla-lint: allow_item(L1, pivot permutation arrays have length n by construction)
    pub fn slogdet(&self) -> (f64, f64) {
        let mut sign = 1.0f64;
        let mut logabs = 0.0f64;
        for j in 0..self.n {
            let mut d = 0.0;
            for &(i, v) in &self.u_cols[j] {
                if i == j {
                    d = v;
                }
            }
            if d == 0.0 {
                return (0.0, f64::NEG_INFINITY);
            }
            if d < 0.0 {
                sign = -sign;
            }
            logabs += d.abs().ln();
        }
        // permutation parity of pinv (row -> position): (-1)^(n - cycles)
        let mut seen = vec![false; self.n];
        for start in 0..self.n {
            if seen[start] {
                continue;
            }
            let mut len = 0usize;
            let mut cur = start;
            while !seen[cur] {
                seen[cur] = true;
                cur = self.pinv[cur];
                len += 1;
            }
            if len % 2 == 0 {
                sign = -sign;
            }
        }
        (sign, logabs)
    }

    /// Solve A x = b.
    // rsla-lint: allow_item(L1, pivot and column indices were bounds-checked at factorization)
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.n {
            return Err(crate::error::Error::InvalidProblem(format!(
                "rhs length {} != n {}",
                b.len(),
                self.n
            )));
        }
        // forward: L y = P b, working in original-row space
        let mut work = b.to_vec();
        let mut y = vec![0f64; self.n];
        for k in 0..self.n {
            let r = self.prow[k];
            let yk = work[r];
            y[k] = yk;
            if yk != 0.0 {
                for &(rr, lv) in &self.l_cols[k] {
                    work[rr] -= yk * lv;
                }
            }
        }
        // backward: U x = y (columns right-to-left)
        let mut x = y;
        for j in (0..self.n).rev() {
            let mut diag = 0.0;
            for &(i, v) in &self.u_cols[j] {
                if i == j {
                    diag = v;
                }
            }
            if diag == 0.0 {
                return Err(Error::Breakdown {
                    at: j,
                    reason: "zero U diagonal".into(),
                });
            }
            let xj = x[j] / diag;
            x[j] = xj;
            if xj != 0.0 {
                for &(i, v) in &self.u_cols[j] {
                    if i < j {
                        x[i] -= v * xj;
                    }
                }
            }
        }
        Ok(x)
    }

    /// Allocation-free variant of [`SparseLu::solve`]: writes the
    /// solution into `out` using `scratch` (both length n) as the
    /// forward-sweep workspace.  Performs the identical floating-point
    /// operation sequence as `solve`, so results are bitwise equal —
    /// only the buffer ownership differs (callers in per-Krylov-
    /// iteration positions reuse both buffers across applications).
    // rsla-lint: allow_item(L1, pivot and column indices were bounds-checked at factorization)
    pub fn solve_into(&self, b: &[f64], out: &mut [f64], scratch: &mut [f64]) -> Result<()> {
        if b.len() != self.n || out.len() != self.n || scratch.len() != self.n {
            return Err(crate::error::Error::InvalidProblem(format!(
                "solve_into buffer length mismatch (n = {})",
                self.n
            )));
        }
        // forward: L y = P b — `scratch` plays `work`, `out` plays `y`
        scratch.copy_from_slice(b);
        for k in 0..self.n {
            let r = self.prow[k];
            let yk = scratch[r];
            out[k] = yk;
            if yk != 0.0 {
                for &(rr, lv) in &self.l_cols[k] {
                    scratch[rr] -= yk * lv;
                }
            }
        }
        // backward: U x = y, in place on `out`
        for j in (0..self.n).rev() {
            let mut diag = 0.0;
            for &(i, v) in &self.u_cols[j] {
                if i == j {
                    diag = v;
                }
            }
            if diag == 0.0 {
                return Err(Error::Breakdown {
                    at: j,
                    reason: "zero U diagonal".into(),
                });
            }
            let xj = out[j] / diag;
            out[j] = xj;
            if xj != 0.0 {
                for &(i, v) in &self.u_cols[j] {
                    if i < j {
                        out[i] -= v * xj;
                    }
                }
            }
        }
        Ok(())
    }

    /// Solve A^T x = b (the adjoint solve reuses the same factorization,
    /// paper §3.2.3: "reusing the same backend and, where applicable, the
    /// same factorization").  From P A = L U: A^T = U^T L^T P.
    // rsla-lint: allow_item(L1, pivot and column indices were bounds-checked at factorization)
    pub fn solve_t(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.n {
            return Err(crate::error::Error::InvalidProblem(format!(
                "rhs length {} != n {}",
                b.len(),
                self.n
            )));
        }
        // forward: U^T z = b (columns left-to-right; U^T is lower)
        let mut z = b.to_vec();
        for j in 0..self.n {
            let mut diag = 0.0;
            let mut s = z[j];
            for &(i, v) in &self.u_cols[j] {
                if i == j {
                    diag = v;
                } else {
                    s -= v * z_at(&z, i);
                }
            }
            if diag == 0.0 {
                return Err(Error::Breakdown {
                    at: j,
                    reason: "zero U diagonal".into(),
                });
            }
            z[j] = s / diag;
        }
        // backward: L^T w = z (unit diagonal; columns right-to-left)
        let mut w = z;
        for k in (0..self.n).rev() {
            let mut s = w[k];
            for &(rr, lv) in &self.l_cols[k] {
                // L[rr', k] with rr original row; its pivot position is pinv[rr]
                s -= lv * w_at(&w, self.pinv[rr]);
            }
            w[k] = s;
        }
        // x = P^T w: x[row] = w[pinv[row]]
        let mut x = vec![0f64; self.n];
        for r in 0..self.n {
            x[r] = w[self.pinv[r]];
        }
        Ok(x)
    }
}

#[inline]
// rsla-lint: allow_item(L1, index is a recorded pivot position < n)
fn z_at(z: &[f64], i: usize) -> f64 {
    z[i]
}

#[inline]
// rsla-lint: allow_item(L1, index is a recorded pivot position < n)
fn w_at(w: &[f64], i: usize) -> f64 {
    w[i]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::{random_nonsymmetric, random_spd};
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn solves_nonsymmetric() {
        let mut rng = Prng::new(1);
        let a = random_nonsymmetric(&mut rng, 80, 5);
        let f = SparseLu::factor(&a).unwrap();
        let b = rng.normal_vec(80);
        let x = f.solve(&b).unwrap();
        assert!(util::rel_l2(&a.matvec(&x), &b) < 1e-11);
    }

    #[test]
    fn solves_poisson_to_machine_precision() {
        let g = 14;
        let sys = poisson2d(g, None);
        let f = SparseLu::factor(&sys.matrix).unwrap();
        let mut rng = Prng::new(2);
        let b = rng.normal_vec(g * g);
        let x = f.solve(&b).unwrap();
        assert!(util::rel_l2(&sys.matrix.matvec(&x), &b) < 1e-12);
    }

    #[test]
    fn transpose_solve() {
        let mut rng = Prng::new(3);
        let a = random_nonsymmetric(&mut rng, 50, 4);
        let f = SparseLu::factor(&a).unwrap();
        let b = rng.normal_vec(50);
        let x = f.solve_t(&b).unwrap();
        let mut atx = vec![0.0; 50];
        a.spmv_t(&x, &mut atx);
        assert!(util::rel_l2(&atx, &b) < 1e-11);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        use crate::sparse::Coo;
        // [[0, 1], [1, 0]] needs a row swap
        let mut coo = Coo::new(2, 2);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        let a = coo.to_csr();
        let f = SparseLu::factor(&a).unwrap();
        let x = f.solve(&[3.0, 7.0]).unwrap();
        assert!((x[0] - 7.0).abs() < 1e-14);
        assert!((x[1] - 3.0).abs() < 1e-14);
    }

    #[test]
    fn singular_matrix_breaks_down() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 1.0);
        // row/col 2 empty -> structurally singular
        let a = coo.to_csr();
        assert!(matches!(
            SparseLu::factor(&a),
            Err(Error::Breakdown { .. })
        ));
    }

    #[test]
    fn fill_cap_aborts_with_oom() {
        let g = 12;
        let sys = poisson2d(g, None);
        match SparseLu::factor_with_cap(&sys.matrix, 50) {
            Err(Error::OutOfMemory { .. }) => {}
            other => panic!("expected OOM, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn spd_matches_cholesky() {
        let mut rng = Prng::new(4);
        let a = random_spd(&mut rng, 40, 3, 1.5);
        let b = rng.normal_vec(40);
        let xl = SparseLu::factor(&a).unwrap().solve(&b).unwrap();
        let xc = super::super::EnvelopeCholesky::factor(&a).unwrap().solve(&b);
        assert!(util::max_abs_diff(&xl, &xc) < 1e-8);
    }

    #[test]
    fn refactor_same_values_is_bitwise_identical() {
        let mut rng = Prng::new(21);
        let a = random_nonsymmetric(&mut rng, 60, 4);
        let (f1, sym) = SparseLu::factor_recording(&a, usize::MAX).unwrap();
        let f2 = SparseLu::refactor(&sym, &a, usize::MAX).unwrap();
        let b = rng.normal_vec(60);
        let x1 = f1.solve(&b).unwrap();
        let x2 = f2.solve(&b).unwrap();
        assert_eq!(x1, x2, "refactor with unchanged values must replay bitwise");
        let t1 = f1.solve_t(&b).unwrap();
        let t2 = f2.solve_t(&b).unwrap();
        assert_eq!(t1, t2);
    }

    #[test]
    fn refactor_new_values_solves_correctly() {
        let mut rng = Prng::new(22);
        let a = random_nonsymmetric(&mut rng, 50, 4);
        let (_, sym) = SparseLu::factor_recording(&a, usize::MAX).unwrap();
        // perturb values mildly so the recorded pivot order stays valid
        let mut a2 = a.clone();
        for v in a2.vals.iter_mut() {
            *v *= 1.0 + 0.01 * rng.normal();
        }
        let f = SparseLu::refactor(&sym, &a2, usize::MAX).unwrap();
        let b = rng.normal_vec(50);
        let x = f.solve(&b).unwrap();
        assert!(util::rel_l2(&a2.matvec(&x), &b) < 1e-9);
        let xt = f.solve_t(&b).unwrap();
        let mut atx = vec![0.0; 50];
        a2.spmv_t(&xt, &mut atx);
        assert!(util::rel_l2(&atx, &b) < 1e-9);
    }

    #[test]
    fn recording_factor_matches_plain_factor_solutions() {
        let mut rng = Prng::new(23);
        let a = random_nonsymmetric(&mut rng, 40, 4);
        let b = rng.normal_vec(40);
        let x_plain = SparseLu::factor(&a).unwrap().solve(&b).unwrap();
        let (f, sym) = SparseLu::factor_recording(&a, usize::MAX).unwrap();
        let x_rec = f.solve(&b).unwrap();
        assert!(util::max_abs_diff(&x_plain, &x_rec) < 1e-10);
        // recording's fill counter excludes the n implicit unit diagonals
        // that SparseLu::fill() adds
        assert_eq!(sym.fill(), f.fill() - 40);
    }

    #[test]
    fn refactor_zero_pivot_is_breakdown() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 1.0);
        let a = coo.to_csr();
        let (_, sym) = SparseLu::factor_recording(&a, usize::MAX).unwrap();
        let mut a2 = a.clone();
        a2.vals[0] = 0.0; // kills the recorded pivot of column 0
        assert!(matches!(
            SparseLu::refactor(&sym, &a2, usize::MAX),
            Err(Error::Breakdown { .. })
        ));
    }

    #[test]
    fn refactor_honors_fill_cap() {
        let g = 12;
        let sys = poisson2d(g, None);
        let (_, sym) = SparseLu::factor_recording(&sys.matrix, usize::MAX).unwrap();
        match SparseLu::refactor(&sym, &sys.matrix, 50) {
            Err(Error::OutOfMemory { .. }) => {}
            other => panic!("expected OOM, got {:?}", other.map(|_| ())),
        }
    }

    #[test]
    fn solve_and_solve_t_agree_on_symmetric() {
        let g = 8;
        let sys = poisson2d(g, None);
        let f = SparseLu::factor(&sys.matrix).unwrap();
        let mut rng = Prng::new(5);
        let b = rng.normal_vec(g * g);
        let x = f.solve(&b).unwrap();
        let xt = f.solve_t(&b).unwrap();
        assert!(util::max_abs_diff(&x, &xt) < 1e-9);
    }
}
