//! Fill-reducing orderings.
//!
//! Reverse Cuthill–McKee minimizes the matrix *envelope*, which is
//! exactly what [`super::cholesky::EnvelopeCholesky`] stores; on 2D grid
//! problems RCM recovers the O(n^1.5) profile the paper's direct-solver
//! fill-in discussion assumes.

use crate::sparse::Csr;

/// Reverse Cuthill–McKee ordering of the symmetrized adjacency of `a`.
/// Returns `perm` with new index i holding old index perm[i] (new->old).
// rsla-lint: allow_item(L1, adjacency lists index the 0..n vertex set they were built from)
pub fn rcm(a: &Csr) -> Vec<usize> {
    let n = a.nrows;
    // symmetrized adjacency (pattern of A + A^T, no diagonal)
    let at = a.transpose();
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); n];
    for r in 0..n {
        for &c in a.row(r).0.iter().chain(at.row(r).0) {
            if c != r {
                adj[r].push(c);
            }
        }
    }
    for l in adj.iter_mut() {
        l.sort_unstable();
        l.dedup();
    }
    let deg: Vec<usize> = adj.iter().map(|l| l.len()).collect();

    let mut visited = vec![false; n];
    let mut order = Vec::with_capacity(n);
    // process every connected component
    loop {
        // pseudo-peripheral start: unvisited vertex of minimum degree
        let start = match (0..n).filter(|&i| !visited[i]).min_by_key(|&i| deg[i]) {
            Some(s) => s,
            None => break,
        };
        let mut queue = std::collections::VecDeque::new();
        queue.push_back(start);
        visited[start] = true;
        while let Some(v) = queue.pop_front() {
            order.push(v);
            let mut nbrs: Vec<usize> = adj[v].iter().copied().filter(|&u| !visited[u]).collect();
            nbrs.sort_unstable_by_key(|&u| deg[u]);
            for u in nbrs {
                visited[u] = true;
                queue.push_back(u);
            }
        }
    }
    order.reverse(); // the "R" in RCM
    order
}

/// Envelope (profile) size of a symmetric matrix under its current
/// ordering: sum over rows of (i - first_col(i) + 1).  This is exactly
/// the storage EnvelopeCholesky will allocate.
pub fn envelope_size(a: &Csr) -> usize {
    let mut total = 0usize;
    for r in 0..a.nrows {
        let (cols, _) = a.row(r);
        let first = cols.iter().copied().filter(|&c| c <= r).min().unwrap_or(r);
        total += r - first + 1;
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;

    #[test]
    fn rcm_is_a_permutation() {
        let sys = poisson2d(10, None);
        let p = rcm(&sys.matrix);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn rcm_does_not_blow_up_grid_envelope() {
        // natural row-major ordering of a g x g grid already has optimal
        // O(n * g) envelope; RCM must stay within ~2x of it.
        let sys = poisson2d(16, None);
        let natural = envelope_size(&sys.matrix);
        let p = rcm(&sys.matrix);
        let reordered = sys.matrix.permute_sym(&p);
        let after = envelope_size(&reordered);
        assert!(
            after <= 2 * natural,
            "RCM envelope {after} vs natural {natural}"
        );
    }

    #[test]
    fn rcm_shrinks_shuffled_grid_envelope() {
        use crate::util::Prng;
        let sys = poisson2d(16, None);
        let mut rng = Prng::new(9);
        let mut shuffle: Vec<usize> = (0..sys.matrix.nrows).collect();
        rng.shuffle(&mut shuffle);
        let scrambled = sys.matrix.permute_sym(&shuffle);
        let before = envelope_size(&scrambled);
        let p = rcm(&scrambled);
        let after = envelope_size(&scrambled.permute_sym(&p));
        assert!(
            after * 3 < before,
            "RCM should fix scrambled ordering: {after} vs {before}"
        );
    }

    #[test]
    fn handles_disconnected_graphs() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(4, 4);
        for i in 0..4 {
            coo.push(i, i, 1.0);
        }
        coo.push(0, 1, -1.0);
        coo.push(1, 0, -1.0);
        // nodes 2, 3 isolated
        let p = rcm(&coo.to_csr());
        assert_eq!(p.len(), 4);
    }
}
