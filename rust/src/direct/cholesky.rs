//! Envelope (profile / skyline) Cholesky factorization.
//!
//! Stores each row of L densely from its first nonzero column to the
//! diagonal (the *envelope*), which Cholesky provably does not enlarge.
//! With RCM ordering a 2D 5-point grid has envelope O(n^1.5) — the same
//! fill law the paper quotes for sparse direct solvers, so the factor
//! bytes we report in Table 3 follow the paper's asymptotics by
//! construction of the algorithm, not by a fitted model.

use crate::error::{Error, Result};
use crate::sparse::Csr;

/// L factor in skyline storage: row i occupies `data[rowptr[i]..rowptr[i+1]]`
/// covering columns `first[i]..=i`.
pub struct EnvelopeCholesky {
    n: usize,
    first: Vec<usize>,
    rowptr: Vec<usize>,
    data: Vec<f64>,
    /// new -> old permutation if factored with reordering (None = natural).
    perm: Option<Vec<usize>>,
}

/// The pattern-only half of an envelope Cholesky factorization: the
/// (optional RCM) permutation, the envelope structure, and a scatter
/// map from original CSR value slots into the skyline array.
///
/// Unlike LU, Cholesky needs no pivoting, so this is a *true* symbolic
/// phase — it depends only on the sparsity pattern and can be computed
/// once per pattern and reused for every value assignment
/// ([`EnvelopeCholesky::factor_numeric`]).
pub struct CholSymbolic {
    n: usize,
    perm: Option<Vec<usize>>,
    first: Vec<usize>,
    rowptr: Vec<usize>,
    /// original CSR value index -> slot in the skyline data array;
    /// `usize::MAX` for entries that land in the (dropped) upper
    /// triangle of the permuted matrix.
    scatter: Vec<usize>,
}

impl CholSymbolic {
    /// Analyze the pattern of `a` (values are ignored).  With
    /// `use_rcm`, an RCM reordering is computed first — RCM is itself
    /// pattern-only, so the whole analysis is value-independent.
    // rsla-lint: allow_item(L1, column pointers and envelope row starts are built in-bounds by construction)
    pub fn analyze(a: &Csr, use_rcm: bool) -> Result<Self> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("cholesky needs square".into()));
        }
        let n = a.nrows;
        let (perm, inv): (Option<Vec<usize>>, Vec<usize>) = if use_rcm {
            let p = super::ordering::rcm(a);
            let mut inv = vec![0usize; n];
            for (new, &old) in p.iter().enumerate() {
                inv[old] = new;
            }
            (Some(p), inv)
        } else {
            (None, (0..n).collect())
        };
        // envelope of the permuted pattern: first lower column per row
        let mut first: Vec<usize> = (0..n).collect();
        for r in 0..n {
            let (cols, _) = a.row(r);
            let pr = inv[r];
            for &c in cols {
                let pc = inv[c];
                if pc <= pr && pc < first[pr] {
                    first[pr] = pc;
                }
            }
        }
        let mut rowptr = vec![0usize; n + 1];
        for r in 0..n {
            rowptr[r + 1] = rowptr[r] + (r - first[r] + 1);
        }
        // scatter map original value slots -> skyline slots
        let mut scatter = vec![usize::MAX; a.nnz()];
        for r in 0..n {
            let pr = inv[r];
            for k in a.indptr[r]..a.indptr[r + 1] {
                let pc = inv[a.indices[k]];
                if pc <= pr {
                    scatter[k] = rowptr[pr] + (pc - first[pr]);
                }
            }
        }
        Ok(CholSymbolic {
            n,
            perm,
            first,
            rowptr,
            scatter,
        })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Skyline slots the numeric phase will allocate (f64 count).
    // rsla-lint: allow_item(L1, row_start has n+1 entries by construction)
    pub fn predicted_fill(&self) -> usize {
        self.rowptr[self.n]
    }

    /// Bytes held by the symbolic structure itself.
    pub fn bytes(&self) -> u64 {
        ((self.first.len() + self.rowptr.len() + self.scatter.len()) * 8) as u64
            + self.perm.as_ref().map_or(0, |p| (p.len() * 8) as u64)
    }
}

/// Jennings row-Cholesky within a fixed envelope; shared by the cold
/// and the numeric-refactorization paths so both run the identical
/// floating-point schedule (cached refactorized solves are bit-equal to
/// cold-factorized ones).
// rsla-lint: allow_item(L1, envelope layout pins row_start/cols bounds as loop invariants)
fn jennings_factor(n: usize, first: &[usize], rowptr: &[usize], data: &mut [f64]) -> Result<()> {
    for i in 0..n {
        let fi = first[i];
        for j in fi..i {
            let fj = first[j];
            let lo = fi.max(fj);
            // s = data[i][j] - sum_k L[i,k] L[j,k], k in [lo, j)
            let mut s = data[rowptr[i] + (j - fi)];
            if lo < j {
                let ri = &data[rowptr[i] + (lo - fi)..rowptr[i] + (j - fi)];
                let rj = &data[rowptr[j] + (lo - fj)..rowptr[j] + (j - fj)];
                s -= crate::util::dot(ri, rj);
            }
            let djj = data[rowptr[j] + (j - first[j])];
            data[rowptr[i] + (j - fi)] = s / djj;
        }
        let mut d = data[rowptr[i] + (i - fi)];
        for k in fi..i {
            let lik = data[rowptr[i] + (k - fi)];
            d -= lik * lik;
        }
        if d <= 0.0 || !d.is_finite() {
            return Err(Error::Breakdown {
                at: i,
                reason: format!("non-positive pivot {d:.3e} (matrix not SPD?)"),
            });
        }
        data[rowptr[i] + (i - fi)] = d.sqrt();
    }
    Ok(())
}

impl EnvelopeCholesky {
    /// Predicted factor storage (f64 count) for `a` under its current
    /// ordering — used by backends for the pre-factorization OOM check.
    pub fn predicted_fill(a: &Csr) -> usize {
        super::ordering::envelope_size(a)
    }

    /// Factor `a` (must be SPD) in its natural ordering.
    pub fn factor(a: &Csr) -> Result<Self> {
        Self::factor_inner(a, None)
    }

    /// RCM-reorder then factor; solves remember the permutation.
    pub fn factor_rcm(a: &Csr) -> Result<Self> {
        let perm = super::ordering::rcm(a);
        let pa = a.permute_sym(&perm);
        Self::factor_inner(&pa, Some(perm))
    }

    // rsla-lint: allow_item(L1, envelope layout pins row_start/cols bounds as loop invariants)
    fn factor_inner(a: &Csr, perm: Option<Vec<usize>>) -> Result<Self> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("cholesky needs square".into()));
        }
        let n = a.nrows;
        // envelope: first lower-triangle column per row
        let mut first = vec![0usize; n];
        for r in 0..n {
            let (cols, _) = a.row(r);
            first[r] = cols.iter().copied().filter(|&c| c <= r).min().unwrap_or(r);
        }
        let mut rowptr = vec![0usize; n + 1];
        for r in 0..n {
            rowptr[r + 1] = rowptr[r] + (r - first[r] + 1);
        }
        let mut data = vec![0f64; rowptr[n]];
        // scatter A's lower triangle into the skyline
        for r in 0..n {
            let (cols, vals) = a.row(r);
            for (c, v) in cols.iter().zip(vals) {
                if *c <= r {
                    data[rowptr[r] + (c - first[r])] = *v;
                }
            }
        }
        jennings_factor(n, &first, &rowptr, &mut data)?;
        Ok(EnvelopeCholesky {
            n,
            first,
            rowptr,
            data,
            perm,
        })
    }

    /// Numeric-only (re)factorization: scatter `vals` (bound to the
    /// pattern `sym` was analyzed on) through the precomputed envelope
    /// and run the numeric sweep.  No RCM, no envelope computation, no
    /// permuted-matrix materialization — only the O(envelope) numeric
    /// work.  Bit-identical to [`EnvelopeCholesky::factor_rcm`] /
    /// [`EnvelopeCholesky::factor`] on the same values.
    // rsla-lint: allow_item(L1, values buffer length is checked against the symbolic layout at entry)
    pub fn factor_numeric(sym: &CholSymbolic, vals: &[f64]) -> Result<Self> {
        if vals.len() != sym.scatter.len() {
            return Err(Error::InvalidProblem(format!(
                "factor_numeric: {} values != pattern nnz {}",
                vals.len(),
                sym.scatter.len()
            )));
        }
        let n = sym.n;
        let mut data = vec![0f64; sym.rowptr[n]];
        for (k, &slot) in sym.scatter.iter().enumerate() {
            if slot != usize::MAX {
                data[slot] = vals[k];
            }
        }
        jennings_factor(n, &sym.first, &sym.rowptr, &mut data)?;
        Ok(EnvelopeCholesky {
            n,
            first: sym.first.clone(),
            rowptr: sym.rowptr.clone(),
            data,
            perm: sym.perm.clone(),
        })
    }

    /// Matrix dimension.
    pub fn n(&self) -> usize {
        self.n
    }

    /// Stored factor values (the measured fill).
    pub fn fill(&self) -> usize {
        self.data.len()
    }

    /// Factor bytes held (for memory accounting).
    pub fn bytes(&self) -> u64 {
        (self.data.len() * 8 + self.rowptr.len() * 8 + self.first.len() * 8) as u64
    }

    /// Solve A x = b via L L^T with the stored permutation.
    // rsla-lint: allow_item(L1, triangular sweep indices come from the validated envelope layout)
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        assert_eq!(b.len(), self.n);
        let pb: Vec<f64> = match &self.perm {
            Some(p) => p.iter().map(|&old| b[old]).collect(),
            None => b.to_vec(),
        };
        // forward: L y = pb
        let mut y = pb;
        for i in 0..self.n {
            let fi = self.first[i];
            let mut s = y[i];
            let row = &self.data[self.rowptr[i]..self.rowptr[i + 1]];
            for (k, c) in (fi..i).enumerate() {
                s -= row[k] * y[c];
            }
            y[i] = s / row[i - fi];
        }
        // backward: L^T x = y (column sweep over L rows)
        let mut x = y;
        for i in (0..self.n).rev() {
            let fi = self.first[i];
            let row = &self.data[self.rowptr[i]..self.rowptr[i + 1]];
            let xi = x[i] / row[i - fi];
            x[i] = xi;
            for (k, c) in (fi..i).enumerate() {
                x[c] -= row[k] * xi;
            }
        }
        match &self.perm {
            Some(p) => {
                let mut out = vec![0.0; self.n];
                for (new, &old) in p.iter().enumerate() {
                    out[old] = x[new];
                }
                out
            }
            None => x,
        }
    }

    /// Multi-RHS solve (shared factorization — the paper's batched solve
    /// over a shared pattern reuses one symbolic+numeric factorization).
    pub fn solve_many(&self, bs: &[Vec<f64>]) -> Vec<Vec<f64>> {
        bs.iter().map(|b| self.solve(b)).collect()
    }

    /// Allocation-free variant of [`EnvelopeCholesky::solve`]: writes
    /// the solution into `out` using `scratch` (both length n) for the
    /// permuted-space sweeps.  Identical floating-point operation
    /// sequence as `solve`, so results are bitwise equal.
    // rsla-lint: allow_item(L1, triangular sweep indices come from the validated envelope layout)
    pub fn solve_into(&self, b: &[f64], out: &mut [f64], scratch: &mut [f64]) {
        assert_eq!(b.len(), self.n);
        assert_eq!(out.len(), self.n);
        assert_eq!(scratch.len(), self.n);
        // permute b into the working buffer (identity when unpermuted)
        let work: &mut [f64] = match &self.perm {
            Some(p) => {
                for (new, &old) in p.iter().enumerate() {
                    scratch[new] = b[old];
                }
                &mut *scratch
            }
            None => {
                out.copy_from_slice(b);
                &mut *out
            }
        };
        // forward: L y = pb
        for i in 0..self.n {
            let fi = self.first[i];
            let mut s = work[i];
            let row = &self.data[self.rowptr[i]..self.rowptr[i + 1]];
            for (k, c) in (fi..i).enumerate() {
                s -= row[k] * work[c];
            }
            work[i] = s / row[i - fi];
        }
        // backward: L^T x = y
        for i in (0..self.n).rev() {
            let fi = self.first[i];
            let row = &self.data[self.rowptr[i]..self.rowptr[i + 1]];
            let xi = work[i] / row[i - fi];
            work[i] = xi;
            for (k, c) in (fi..i).enumerate() {
                work[c] -= row[k] * xi;
            }
        }
        if let Some(p) = &self.perm {
            // work aliases scratch here; un-permute into out
            for (new, &old) in p.iter().enumerate() {
                out[old] = scratch[new];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::random_spd;
    use crate::sparse::poisson::{kappa_star, poisson2d};
    use crate::util::{self, Prng};

    #[test]
    fn factors_and_solves_poisson() {
        let g = 16;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let f = EnvelopeCholesky::factor(&sys.matrix).unwrap();
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let x = f.solve(&b);
        assert!(util::rel_l2(&sys.matrix.matvec(&x), &b) < 1e-11);
    }

    #[test]
    fn rcm_solve_matches_natural() {
        let g = 12;
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(1);
        let b = rng.normal_vec(g * g);
        let x1 = EnvelopeCholesky::factor(&sys.matrix).unwrap().solve(&b);
        let x2 = EnvelopeCholesky::factor_rcm(&sys.matrix).unwrap().solve(&b);
        assert!(util::max_abs_diff(&x1, &x2) < 1e-9);
    }

    #[test]
    fn random_spd_machine_precision() {
        let mut rng = Prng::new(2);
        let a = random_spd(&mut rng, 60, 4, 2.0);
        let f = EnvelopeCholesky::factor_rcm(&a).unwrap();
        let b = rng.normal_vec(60);
        let x = f.solve(&b);
        assert!(util::rel_l2(&a.matvec(&x), &b) < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, -1.0);
        let a = coo.to_csr();
        assert!(matches!(
            EnvelopeCholesky::factor(&a),
            Err(Error::Breakdown { .. })
        ));
    }

    #[test]
    fn fill_follows_n_to_three_halves_on_grids() {
        // envelope of natural-ordered g x g 5-point grid ~ n * g = n^1.5
        let f16 = EnvelopeCholesky::predicted_fill(&poisson2d(16, None).matrix) as f64;
        let f32_ = EnvelopeCholesky::predicted_fill(&poisson2d(32, None).matrix) as f64;
        let alpha = (f32_ / f16).log2() / 2.0; // n quadruples per g doubling
        assert!(
            (1.3..1.7).contains(&alpha),
            "fill exponent {alpha} not ~1.5"
        );
    }

    #[test]
    fn factor_numeric_is_bitwise_identical_to_cold_rcm() {
        let g = 14;
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let cold = EnvelopeCholesky::factor_rcm(&sys.matrix).unwrap();
        let sym = CholSymbolic::analyze(&sys.matrix, true).unwrap();
        let warm = EnvelopeCholesky::factor_numeric(&sym, &sys.matrix.vals).unwrap();
        assert_eq!(cold.data, warm.data, "numeric refactor must replay bitwise");
        let mut rng = Prng::new(7);
        let b = rng.normal_vec(g * g);
        assert_eq!(cold.solve(&b), warm.solve(&b));
    }

    #[test]
    fn factor_numeric_natural_matches_cold_natural() {
        let mut rng = Prng::new(8);
        let a = random_spd(&mut rng, 50, 3, 2.0);
        let cold = EnvelopeCholesky::factor(&a).unwrap();
        let sym = CholSymbolic::analyze(&a, false).unwrap();
        let warm = EnvelopeCholesky::factor_numeric(&sym, &a.vals).unwrap();
        assert_eq!(cold.data, warm.data);
    }

    #[test]
    fn factor_numeric_reuses_symbolic_across_values() {
        let g = 10;
        let sys = poisson2d(g, None);
        let sym = CholSymbolic::analyze(&sys.matrix, true).unwrap();
        assert_eq!(sym.predicted_fill(), sym.rowptr[sym.n]);
        let mut rng = Prng::new(9);
        for scale in [0.5, 1.0, 3.0] {
            let vals: Vec<f64> = sys.matrix.vals.iter().map(|v| v * scale).collect();
            let f = EnvelopeCholesky::factor_numeric(&sym, &vals).unwrap();
            let b = rng.normal_vec(g * g);
            let x = f.solve(&b);
            let a = crate::sparse::Pattern::of(&sys.matrix).with_vals(vals);
            assert!(util::rel_l2(&a.matvec(&x), &b) < 1e-11);
        }
    }

    #[test]
    fn factor_numeric_rejects_indefinite_values() {
        use crate::sparse::Coo;
        let mut coo = Coo::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(1, 1, 1.0);
        let a = coo.to_csr();
        let sym = CholSymbolic::analyze(&a, false).unwrap();
        assert!(matches!(
            EnvelopeCholesky::factor_numeric(&sym, &[1.0, -1.0]),
            Err(Error::Breakdown { .. })
        ));
    }

    #[test]
    fn identity_solve() {
        let a = Csr::identity(5);
        let f = EnvelopeCholesky::factor(&a).unwrap();
        let b = vec![1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(f.solve(&b), b);
        assert_eq!(f.fill(), 5);
    }

    #[test]
    fn multi_rhs() {
        let g = 8;
        let sys = poisson2d(g, None);
        let f = EnvelopeCholesky::factor(&sys.matrix).unwrap();
        let mut rng = Prng::new(3);
        let bs: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(g * g)).collect();
        for (x, b) in f.solve_many(&bs).iter().zip(&bs) {
            assert!(util::rel_l2(&sys.matrix.matvec(x), b) < 1e-10);
        }
    }
}
