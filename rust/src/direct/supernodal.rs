//! Supernodal blocked Cholesky: elimination-tree supernode detection in
//! the symbolic tier and a dense-panel numeric phase.
//!
//! The envelope kernel in [`super::cholesky`] factors one row at a time
//! with scalar dots.  This module detects *supernodes* — runs of
//! consecutive columns whose factor patterns nest ([`parent[j-1] == j`
//! and `|L(:,j-1)| == |L(:,j)| + 1`) — merges small ones up the etree
//! under a relaxed-amalgamation bound, and factors each supernode as a
//! 64-byte-aligned dense panel: descendant contributions become dense
//! rank-k updates and the diagonal block a dense in-panel Cholesky, all
//! running through the fixed-schedule microkernels in
//! [`crate::sparse::kernels`] (`panel_dot` / `panel_dot2` /
//! `panel_sub_scaled`).
//!
//! Determinism contract: the partition and every floating-point
//! schedule depend only on the sparsity pattern and the analysis
//! options, never on values, and cold factorization and warm
//! refactorization share one numeric body — so refactor-vs-cold stays
//! bitwise identical, matching the envelope path's pin.  AVX2 dispatch
//! is decided once per factorization from CPU detection, which is
//! constant within a process.
//!
//! Symbolic enrichment: after amalgamation the panel patterns are
//! recomputed supernode-by-supernode with the same descendant linked
//! lists the numeric phase walks.  Scalar column patterns are *not*
//! closed under descendant updates once amalgamation pads patterns
//! (an enriched descendant pushes rows its scalar columns never had),
//! so containment has to be established against the enriched rows,
//! not the scalar unions.

use std::sync::Arc;

use crate::error::{Error, Result};
use crate::metrics::{names as mn, Registry};
use crate::sparse::align::AlignedVec;
use crate::sparse::kernels::{panel_dot, panel_dot2};
use crate::sparse::Csr;
use crate::trace::{self, names as tn};

/// Hard cap on supernode width: panel triangular solves keep their
/// column accumulator in a stack buffer of this many lanes, so the
/// warm solve path stays allocation-free (see
/// [`super::triangular::sn_backward_solve`]).
pub const SN_MAX_WIDTH: usize = 32;

/// Tuning knobs for supernode detection.  All pattern-only: two
/// analyses of the same pattern with the same options produce the same
/// partition regardless of values.
#[derive(Clone, Copy, Debug)]
pub struct SupernodalOpts {
    /// Maximum panel width (clamped to [`SN_MAX_WIDTH`]).
    pub max_width: usize,
    /// Relaxed-amalgamation slack: merging two etree-adjacent groups
    /// is accepted while `dense_panel_cells <= (1 + relax) * pattern_nz`,
    /// i.e. `relax` bounds the fraction of explicit zeros the dense
    /// panels may carry in exchange for wider rank-k updates.
    pub relax: f64,
    /// Engage the blocked kernel only when some panel reaches this
    /// width; below it the scalar envelope kernel is at least as fast
    /// and the matrix falls back to it.
    pub engage_min_width: usize,
}

impl Default for SupernodalOpts {
    fn default() -> Self {
        SupernodalOpts {
            max_width: 16,
            relax: 0.25,
            engage_min_width: 4,
        }
    }
}

/// Pattern-only supernodal analysis: permutation, supernode partition,
/// enriched per-panel row patterns, panel offsets, and a scatter map
/// from original CSR value slots into panel slots.
///
/// Stored in the factor cache's symbolic tier ([`super::cache::Symbolic`])
/// and shared by every numeric refactorization of the pattern.
pub struct SnCholSymbolic {
    n: usize,
    /// new -> old permutation (None = natural order).
    perm: Option<Vec<usize>>,
    /// Supernode `s` spans permuted columns `sn_ptr[s]..sn_ptr[s+1]`.
    sn_ptr: Vec<usize>,
    /// Concatenated row patterns; supernode `s` owns
    /// `rows[row_ptr[s]..row_ptr[s+1]]`, sorted ascending, and its
    /// first `width` entries are exactly its own columns.
    rows: Vec<usize>,
    row_ptr: Vec<usize>,
    /// f64 offset of each panel in the packed panel array;
    /// `panel_ptr[s+1] - panel_ptr[s] == m_s * w_s` (row-major).
    panel_ptr: Vec<usize>,
    /// Permuted column -> owning supernode.
    col_of_sn: Vec<usize>,
    /// Original CSR value slot -> panel slot (`usize::MAX` = upper
    /// triangle of the permuted matrix, dropped).
    scatter: Vec<usize>,
    /// Widest panel in the partition.
    max_width: usize,
    /// Whether the blocked kernel is worth running for this pattern.
    engaged: bool,
}

impl SnCholSymbolic {
    /// Analyze the pattern of `a` (values ignored).  `use_rcm` mirrors
    /// [`super::cholesky::CholSymbolic::analyze`]; the RCM ordering is
    /// pattern-only so the whole analysis is value-independent.
    // rsla-lint: allow_item(L1, symbolic-tier index arithmetic over arrays this function sizes itself; every index is bounded by n or nnz by construction)
    pub fn analyze(a: &Csr, use_rcm: bool, opts: &SupernodalOpts) -> Result<Self> {
        if a.nrows != a.ncols {
            return Err(Error::InvalidProblem("cholesky needs square".into()));
        }
        let n = a.nrows;
        let max_width = opts.max_width.clamp(1, SN_MAX_WIDTH);
        if n == 0 {
            return Ok(SnCholSymbolic {
                n,
                perm: None,
                sn_ptr: vec![0],
                rows: Vec::new(),
                row_ptr: vec![0],
                panel_ptr: vec![0],
                col_of_sn: Vec::new(),
                scatter: Vec::new(),
                max_width: 0,
                engaged: false,
            });
        }
        let (perm, inv): (Option<Vec<usize>>, Vec<usize>) = if use_rcm {
            let p = super::ordering::rcm(a);
            let mut inv = vec![0usize; n];
            for (new, &old) in p.iter().enumerate() {
                inv[old] = new;
            }
            (Some(p), inv)
        } else {
            (None, (0..n).collect())
        };
        let old_of = |i: usize| -> usize { perm.as_ref().map_or(i, |p| p[i]) };

        // Bucket the permuted lower triangle (pr >= pc) by column; kept
        // alongside the original value index for the scatter map.
        let mut colptr = vec![0usize; n + 1];
        for r in 0..n {
            let (cols, _) = a.row(r);
            let pr = inv[r];
            for &c in cols {
                if pr >= inv[c] {
                    colptr[inv[c] + 1] += 1;
                }
            }
        }
        for j in 0..n {
            colptr[j + 1] += colptr[j];
        }
        let nnz_lower = colptr[n];
        let mut crow = vec![0usize; nnz_lower];
        let mut cvidx = vec![0usize; nnz_lower];
        let mut cursor = colptr.clone();
        for r in 0..n {
            let pr = inv[r];
            for k in a.indptr[r]..a.indptr[r + 1] {
                let pc = inv[a.indices[k]];
                if pr >= pc {
                    crow[cursor[pc]] = pr;
                    cvidx[cursor[pc]] = k;
                    cursor[pc] += 1;
                }
            }
        }

        // Pass 1: elimination tree (Liu) with ancestor path compression.
        let mut parent = vec![usize::MAX; n];
        let mut ancestor = vec![usize::MAX; n];
        for i in 0..n {
            let (cols, _) = a.row(old_of(i));
            for &c in cols {
                let mut j = inv[c];
                if j >= i {
                    continue;
                }
                while j != usize::MAX && j != i {
                    let up = ancestor[j];
                    ancestor[j] = i;
                    if up == usize::MAX {
                        parent[j] = i;
                    }
                    j = up;
                }
            }
        }

        // Pass 2: scalar column counts + patterns of L by row-subtree
        // traversal (walk parent pointers, stop at marked nodes);
        // O(|L|) total.  col_rows[j] comes out sorted because i ascends.
        let mut mark = vec![usize::MAX; n];
        let mut colcount = vec![1usize; n];
        let mut col_rows: Vec<Vec<usize>> = (0..n).map(|j| vec![j]).collect();
        for i in 0..n {
            mark[i] = i;
            let (cols, _) = a.row(old_of(i));
            for &c in cols {
                let mut j = inv[c];
                if j >= i {
                    continue;
                }
                while mark[j] != i {
                    mark[j] = i;
                    colcount[j] += 1;
                    col_rows[j].push(i);
                    j = parent[j];
                }
            }
        }

        // Fundamental supernodes, split at max_width (panel kernels
        // carry a hard width cap for their stack buffers).
        let mut starts = vec![0usize];
        let mut last_start = 0usize;
        for j in 1..n {
            let fundamental = parent[j - 1] == j && colcount[j - 1] == colcount[j] + 1;
            if !fundamental || j - last_start >= max_width {
                starts.push(j);
                last_start = j;
            }
        }
        starts.push(n);

        // Relaxed amalgamation: greedy left-to-right merge of
        // etree-adjacent groups while the dense panel stays within
        // (1 + relax) of the union pattern's nonzeros.  Marker-based
        // union with rollback of rejected candidates.
        let mut merged: Vec<(usize, usize)> = Vec::new();
        let mut gmark = vec![usize::MAX; n];
        let mut stamp = 0usize;
        let mut added: Vec<usize> = Vec::new();
        let mut cur_lo = starts[0];
        let mut cur_hi = starts[1];
        let mut cur_rows = 0usize;
        let mut cur_nz = 0usize;
        stamp += 1;
        for j in cur_lo..cur_hi {
            cur_nz += colcount[j];
            for &r in &col_rows[j] {
                if gmark[r] != stamp {
                    gmark[r] = stamp;
                    cur_rows += 1;
                }
            }
        }
        for g in 1..starts.len() - 1 {
            let lo = starts[g];
            let hi = starts[g + 1];
            let w = hi - cur_lo;
            let mut accept = false;
            if parent[cur_hi - 1] == cur_hi && w <= max_width {
                added.clear();
                let mut cand_nz = cur_nz;
                for j in lo..hi {
                    cand_nz += colcount[j];
                    for &r in &col_rows[j] {
                        if gmark[r] != stamp {
                            gmark[r] = stamp;
                            added.push(r);
                        }
                    }
                }
                let dense = (cur_rows + added.len()) * w;
                if dense as f64 <= (1.0 + opts.relax) * cand_nz as f64 {
                    cur_hi = hi;
                    cur_rows += added.len();
                    cur_nz = cand_nz;
                    accept = true;
                } else {
                    for &r in &added {
                        gmark[r] = usize::MAX;
                    }
                }
            }
            if !accept {
                merged.push((cur_lo, cur_hi));
                cur_lo = lo;
                cur_hi = hi;
                cur_rows = 0;
                cur_nz = 0;
                stamp += 1;
                for j in lo..hi {
                    cur_nz += colcount[j];
                    for &r in &col_rows[j] {
                        if gmark[r] != stamp {
                            gmark[r] = stamp;
                            cur_rows += 1;
                        }
                    }
                }
            }
        }
        merged.push((cur_lo, cur_hi));
        drop(col_rows);

        // Enriched supernodal pass: recompute panel row patterns with
        // the numeric phase's descendant linked lists so patterns are
        // closed under descendant updates even after amalgamation
        // padding.  Also fills the value scatter map in the same sweep.
        let nsuper = merged.len();
        let mut col_of_sn = vec![0usize; n];
        for (s, &(lo, hi)) in merged.iter().enumerate() {
            for j in lo..hi {
                col_of_sn[j] = s;
            }
        }
        let mut head = vec![usize::MAX; nsuper];
        let mut nxt = vec![usize::MAX; nsuper];
        let mut cur = vec![0usize; nsuper];
        let mut pos = vec![0usize; n];
        let mut rows: Vec<usize> = Vec::new();
        let mut row_ptr = vec![0usize];
        let mut panel_ptr = vec![0usize];
        let mut sn_ptr = vec![0usize];
        let mut scatter = vec![usize::MAX; a.nnz()];
        let mut list: Vec<usize> = Vec::new();
        let mut max_w = 0usize;
        for (s, &(lo, hi)) in merged.iter().enumerate() {
            let w = hi - lo;
            max_w = max_w.max(w);
            // fresh stamps disjoint from pass 2's (which used 0..n)
            let st = n + 1 + s;
            list.clear();
            for j in lo..hi {
                mark[j] = st;
                list.push(j);
            }
            for j in lo..hi {
                for k in colptr[j]..colptr[j + 1] {
                    let r = crow[k];
                    if mark[r] != st {
                        mark[r] = st;
                        list.push(r);
                    }
                }
            }
            let mut d = head[s];
            while d != usize::MAX {
                let dn = nxt[d];
                let dr0 = row_ptr[d];
                let dlen = row_ptr[d + 1] - dr0;
                let mut kend = cur[d];
                while kend < dlen && rows[dr0 + kend] < hi {
                    kend += 1;
                }
                // every remaining descendant row propagates upward
                for k in cur[d]..dlen {
                    let r = rows[dr0 + k];
                    if mark[r] != st {
                        mark[r] = st;
                        list.push(r);
                    }
                }
                cur[d] = kend;
                if kend < dlen {
                    let t = col_of_sn[rows[dr0 + kend]];
                    nxt[d] = head[t];
                    head[t] = d;
                }
                d = dn;
            }
            list.sort_unstable();
            debug_assert!(
                list.iter().take(w).copied().eq(lo..hi),
                "panel head must be the supernode's own columns"
            );
            let m = list.len();
            for (k, &r) in list.iter().enumerate() {
                pos[r] = k;
            }
            let pbase = match panel_ptr.last() {
                Some(&p) => p,
                None => 0,
            };
            for j in lo..hi {
                for k in colptr[j]..colptr[j + 1] {
                    scatter[cvidx[k]] = pbase + pos[crow[k]] * w + (j - lo);
                }
            }
            rows.extend_from_slice(&list);
            row_ptr.push(rows.len());
            panel_ptr.push(pbase + m * w);
            sn_ptr.push(hi);
            cur[s] = w;
            if w < m {
                let t = col_of_sn[rows[row_ptr[s] + w]];
                nxt[s] = head[t];
                head[t] = s;
            }
        }

        let engaged = max_w >= opts.engage_min_width.max(1);
        Ok(SnCholSymbolic {
            n,
            perm,
            sn_ptr,
            rows,
            row_ptr,
            panel_ptr,
            col_of_sn,
            scatter,
            max_width: max_w,
            engaged,
        })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of supernodes in the partition.
    pub fn nsuper(&self) -> usize {
        self.sn_ptr.len() - 1
    }

    /// Widest panel in the partition (columns).
    pub fn max_panel_width(&self) -> usize {
        self.max_width
    }

    /// Whether the blocked kernel is engaged for this pattern; when
    /// false, callers should fall back to the envelope column kernel.
    pub fn engaged(&self) -> bool {
        self.engaged
    }

    /// Panel slots the numeric phase will allocate (f64 count,
    /// explicit amalgamation zeros included).
    pub fn predicted_fill(&self) -> usize {
        match self.panel_ptr.last() {
            Some(&p) => p,
            None => 0,
        }
    }

    /// Bytes held by the symbolic structure itself.
    pub fn bytes(&self) -> u64 {
        (((self.sn_ptr.len() + self.rows.len() + self.row_ptr.len() + self.panel_ptr.len())
            + (self.col_of_sn.len() + self.scatter.len()))
            * 8) as u64
            + self.perm.as_ref().map_or(0, |p| (p.len() * 8) as u64)
    }
}

/// Shared numeric body: one floating-point schedule for the cold and
/// warm paths (refactor-vs-cold bitwise pin), compiled twice — once
/// generic, once under `target_feature(avx2)` — and dispatched once per
/// factorization.  Returns the flop count of the blocked phase.
// rsla-lint: allow_item(L1, left-looking kernel over panel offsets the symbolic pass sized; descendant rows are contained in target rows by the enriched-pattern construction)
#[inline(always)]
fn sn_numeric_body(sym: &SnCholSymbolic, panels: &mut [f64]) -> Result<u64> {
    let nsuper = sym.nsuper();
    let mut head = vec![usize::MAX; nsuper];
    let mut nxt = vec![usize::MAX; nsuper];
    let mut cur = vec![0usize; nsuper];
    let mut pos = vec![0usize; sym.n];
    let mut flops = 0u64;
    for s in 0..nsuper {
        let lo = sym.sn_ptr[s];
        let hi = sym.sn_ptr[s + 1];
        let w = hi - lo;
        let r0 = sym.row_ptr[s];
        let m = sym.row_ptr[s + 1] - r0;
        let srows = &sym.rows[r0..r0 + m];
        for (k, &r) in srows.iter().enumerate() {
            pos[r] = k;
        }
        // descendants strictly precede the target in the panel array
        let (done, target) = panels.split_at_mut(sym.panel_ptr[s]);
        let target = &mut target[..m * w];
        let mut d = head[s];
        while d != usize::MAX {
            let dn = nxt[d];
            let dr0 = sym.row_ptr[d];
            let dlen = sym.row_ptr[d + 1] - dr0;
            let dw = sym.sn_ptr[d + 1] - sym.sn_ptr[d];
            let drows = &sym.rows[dr0..dr0 + dlen];
            let dpanel = &done[sym.panel_ptr[d]..sym.panel_ptr[d] + dlen * dw];
            let k0 = cur[d];
            let mut kend = k0;
            while kend < dlen && drows[kend] < hi {
                kend += 1;
            }
            // rank-k update: target[k2, drows[k]-lo] -= <D[k2,:], D[k,:]>
            // over contiguous row-major panel rows, two dots per pass
            // to reuse the loaded D[k,:] operand.
            for k in k0..kend {
                let colk = drows[k] - lo;
                let drow_k = &dpanel[k * dw..(k + 1) * dw];
                let mut k2 = k;
                while k2 + 1 < dlen {
                    let (va, vb) = panel_dot2(
                        drow_k,
                        &dpanel[k2 * dw..(k2 + 1) * dw],
                        &dpanel[(k2 + 1) * dw..(k2 + 2) * dw],
                    );
                    target[pos[drows[k2]] * w + colk] -= va;
                    target[pos[drows[k2 + 1]] * w + colk] -= vb;
                    k2 += 2;
                }
                if k2 < dlen {
                    let v = panel_dot(drow_k, &dpanel[k2 * dw..(k2 + 1) * dw]);
                    target[pos[drows[k2]] * w + colk] -= v;
                }
                flops += (2 * dw * (dlen - k)) as u64;
            }
            cur[d] = kend;
            if kend < dlen {
                let t = sym.col_of_sn[drows[kend]];
                nxt[d] = head[t];
                head[t] = d;
            }
            d = dn;
        }
        // dense in-panel Cholesky of the diagonal block + column scaling
        for c in 0..w {
            let (top, below) = target.split_at_mut((c + 1) * w);
            let crow = &mut top[c * w..];
            let d = crow[c] - panel_dot(&crow[..c], &crow[..c]);
            if d <= 0.0 || !d.is_finite() {
                return Err(Error::Breakdown {
                    at: lo + c,
                    reason: format!("non-positive pivot {d:.3e} (matrix not SPD?)"),
                });
            }
            let lcc = d.sqrt();
            crow[c] = lcc;
            let pivot = &top[c * w..c * w + c];
            for row in below.chunks_exact_mut(w) {
                let v = row[c] - panel_dot(&row[..c], pivot);
                row[c] = v / lcc;
            }
        }
        flops += (m * w * w) as u64;
        cur[s] = w;
        if w < m {
            let t = sym.col_of_sn[srows[w]];
            nxt[s] = head[t];
            head[t] = s;
        }
    }
    Ok(flops)
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn sn_numeric_avx2(sym: &SnCholSymbolic, panels: &mut [f64]) -> Result<u64> {
    sn_numeric_body(sym, panels)
}

fn sn_numeric(sym: &SnCholSymbolic, panels: &mut [f64]) -> Result<u64> {
    #[cfg(target_arch = "x86_64")]
    if crate::sparse::kernels::avx2_available() {
        // SAFETY: gated on runtime AVX2 detection, which is constant
        // within a process (so cold and warm take the same schedule).
        return unsafe { sn_numeric_avx2(sym, panels) };
    }
    sn_numeric_body(sym, panels)
}

/// Supernodal Cholesky factor: the shared symbolic partition plus the
/// packed row-major panels of L.
pub struct SnCholesky {
    sym: Arc<SnCholSymbolic>,
    panels: AlignedVec<f64>,
}

impl SnCholesky {
    /// Numeric (re)factorization of `vals` on the analyzed pattern.
    /// Cold factorization and warm refactorization both come through
    /// here, so they run the identical floating-point schedule.
    // rsla-lint: allow_item(L1, scatter slots index the panel array the symbolic pass sized)
    pub fn factor_numeric(sym: &Arc<SnCholSymbolic>, vals: &[f64]) -> Result<Self> {
        if vals.len() != sym.scatter.len() {
            return Err(Error::InvalidProblem(
                "value array does not match analyzed pattern".into(),
            ));
        }
        let _span = trace::span_arg(tn::DIRECT_SUPERNODAL_NUMERIC, sym.nsuper() as u64);
        let mut panels = AlignedVec::<f64>::zeroed(sym.predicted_fill());
        for (k, &slot) in sym.scatter.iter().enumerate() {
            if slot != usize::MAX {
                panels[slot] = vals[k];
            }
        }
        let flops = sn_numeric(sym, &mut panels)?;
        let reg = Registry::global();
        reg.incr(mn::FACTOR_SUPERNODE_COUNT, sym.nsuper() as u64);
        reg.incr(mn::FACTOR_SUPERNODE_MAX_COLS, sym.max_panel_width() as u64);
        reg.incr(mn::FACTOR_PANEL_FLOPS, flops);
        Ok(SnCholesky {
            sym: sym.clone(),
            panels,
        })
    }

    pub fn n(&self) -> usize {
        self.sym.n
    }

    /// Stored factor entries (f64 count, amalgamation zeros included).
    pub fn fill(&self) -> usize {
        self.panels.len()
    }

    /// Bytes held by the numeric factor (the symbolic structure is
    /// shared and accounted separately by the cache).
    pub fn bytes(&self) -> u64 {
        (self.panels.len() * 8) as u64
    }

    /// Solve `A x = b`.  Delegates to [`Self::solve_into`] so the two
    /// entry points stay bitwise identical.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.sym.n {
            return Err(Error::InvalidProblem("rhs length mismatch".into()));
        }
        let mut out = vec![0.0; self.sym.n];
        let mut scratch = vec![0.0; self.sym.n];
        self.solve_into(b, &mut out, &mut scratch);
        Ok(out)
    }

    /// Allocation-free solve into caller-provided buffers; `scratch`
    /// must be at least `n` long (holds the permuted working vector).
    // rsla-lint: no_alloc
    // rsla-lint: allow_item(L1, permutation gather/scatter and panel slices are bounded by n and the symbolic layout)
    pub fn solve_into(&self, b: &[f64], out: &mut [f64], scratch: &mut [f64]) {
        assert_eq!(b.len(), self.sym.n);
        assert_eq!(out.len(), self.sym.n);
        assert!(scratch.len() >= self.sym.n);
        let sym = &*self.sym;
        let work: &mut [f64] = match &sym.perm {
            Some(p) => {
                for (new, &old) in p.iter().enumerate() {
                    scratch[new] = b[old];
                }
                &mut scratch[..sym.n]
            }
            None => {
                out.copy_from_slice(b);
                &mut out[..]
            }
        };
        super::triangular::sn_forward_solve(
            &sym.sn_ptr,
            &sym.row_ptr,
            &sym.rows,
            &sym.panel_ptr,
            &self.panels,
            work,
        );
        super::triangular::sn_backward_solve(
            &sym.sn_ptr,
            &sym.row_ptr,
            &sym.rows,
            &sym.panel_ptr,
            &self.panels,
            work,
        );
        if let Some(p) = &sym.perm {
            for (new, &old) in p.iter().enumerate() {
                out[old] = scratch[new];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::random_spd;
    use crate::sparse::poisson::poisson2d;
    use crate::util::Prng;

    fn check_solve(a: &Csr, opts: &SupernodalOpts) {
        let sym = Arc::new(SnCholSymbolic::analyze(a, true, opts).unwrap());
        let f = SnCholesky::factor_numeric(&sym, &a.vals).unwrap();
        let n = a.nrows;
        let mut prng = Prng::new(99);
        let b: Vec<f64> = (0..n).map(|_| prng.uniform() - 0.5).collect();
        let x = f.solve(&b).unwrap();
        let ad = a.to_dense();
        let mut resid: f64 = 0.0;
        let mut bnorm: f64 = 0.0;
        for i in 0..n {
            let mut s = -b[i];
            for j in 0..n {
                s += ad[i][j] * x[j];
            }
            resid += s * s;
            bnorm += b[i] * b[i];
        }
        assert!(
            resid.sqrt() <= 1e-9 * bnorm.sqrt().max(1.0),
            "residual {:.3e} too large (max_width={}, relax={})",
            resid.sqrt(),
            opts.max_width,
            opts.relax
        );
    }

    #[test]
    fn supernodal_solve_matches_across_options() {
        let a = random_spd(&mut Prng::new(3), 60, 3, 1.5);
        for (mw, rx) in [(1, 0.0), (4, 0.25), (8, 0.25), (16, 1.0), (32, 0.5)] {
            check_solve(
                &a,
                &SupernodalOpts {
                    max_width: mw,
                    relax: rx,
                    engage_min_width: 1,
                },
            );
        }
        check_solve(&poisson2d(12, None).matrix, &SupernodalOpts::default());
    }

    #[test]
    fn refactor_is_bitwise_deterministic() {
        let a = poisson2d(10, None).matrix;
        let sym = Arc::new(SnCholSymbolic::analyze(&a, true, &SupernodalOpts::default()).unwrap());
        let f1 = SnCholesky::factor_numeric(&sym, &a.vals).unwrap();
        let f2 = SnCholesky::factor_numeric(&sym, &a.vals).unwrap();
        assert_eq!(f1.panels, f2.panels);
    }

    #[test]
    fn solve_into_is_bitwise_equal_to_solve() {
        let a = poisson2d(8, None).matrix;
        let sym = Arc::new(SnCholSymbolic::analyze(&a, true, &SupernodalOpts::default()).unwrap());
        let f = SnCholesky::factor_numeric(&sym, &a.vals).unwrap();
        let n = a.nrows;
        let mut prng = Prng::new(4);
        let b: Vec<f64> = (0..n).map(|_| prng.uniform()).collect();
        let mut out = vec![0.0; n];
        let mut scratch = vec![0.0; n];
        f.solve_into(&b, &mut out, &mut scratch);
        assert_eq!(f.solve(&b).unwrap(), out);
    }

    #[test]
    fn diagonal_pattern_does_not_engage() {
        // width-1 supernodes everywhere: amalgamation has no etree
        // edges to merge along, so the blocked kernel must not engage.
        let a = Csr::identity(24);
        let sym = SnCholSymbolic::analyze(&a, true, &SupernodalOpts::default()).unwrap();
        assert!(!sym.engaged());
        assert_eq!(sym.max_panel_width(), 1);
    }

    #[test]
    fn breakdown_on_non_spd() {
        let a = random_spd(&mut Prng::new(5), 20, 2, 1.5);
        let mut vals = a.vals.to_vec();
        // flip the sign of the whole matrix: -SPD has negative pivots
        for v in vals.iter_mut() {
            *v = -*v;
        }
        let sym = Arc::new(
            SnCholSymbolic::analyze(
                &a,
                true,
                &SupernodalOpts {
                    max_width: 8,
                    relax: 0.25,
                    engage_min_width: 1,
                },
            )
            .unwrap(),
        );
        assert!(matches!(
            SnCholesky::factor_numeric(&sym, &vals),
            Err(Error::Breakdown { .. })
        ));
    }
}
