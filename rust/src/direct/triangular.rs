//! Sparse triangular solves on CSR factors (used by the ILU/IC
//! preconditioners, which store their factors as CSR), and the blocked
//! panel sweeps for supernodal Cholesky factors
//! ([`sn_forward_solve`] / [`sn_backward_solve`]).

use super::supernodal::SN_MAX_WIDTH;
use crate::sparse::kernels::panel_dot;
use crate::sparse::Csr;

/// Solve L x = b where `l` is lower triangular CSR with the diagonal
/// stored as the LAST entry of each row.
// rsla-lint: allow_item(L1, CSR row slices index the validated n-vector)
pub fn lower_solve_csr(l: &Csr, b: &[f64], x: &mut [f64]) {
    debug_assert_eq!(l.nrows, b.len());
    for r in 0..l.nrows {
        let (cols, vals) = l.row(r);
        debug_assert!(!cols.is_empty() && cols[cols.len() - 1] == r, "diag last");
        let mut s = b[r];
        for k in 0..cols.len() - 1 {
            s -= vals[k] * x[cols[k]];
        }
        x[r] = s / vals[cols.len() - 1];
    }
}

/// Solve U x = b where `u` is upper triangular CSR with the diagonal
/// stored as the FIRST entry of each row.
// rsla-lint: allow_item(L1, CSR row slices index the validated n-vector)
pub fn upper_solve_csr(u: &Csr, b: &[f64], x: &mut [f64]) {
    debug_assert_eq!(u.nrows, b.len());
    for r in (0..u.nrows).rev() {
        let (cols, vals) = u.row(r);
        debug_assert!(!cols.is_empty() && cols[0] == r, "diag first");
        let mut s = b[r];
        for k in 1..cols.len() {
            s -= vals[k] * x[cols[k]];
        }
        x[r] = s / vals[0];
    }
}

/// Solve L^T x = b with `l` as in [`lower_solve_csr`] (column sweep).
// rsla-lint: allow_item(L1, CSR row slices index the validated n-vector)
pub fn lower_transpose_solve_csr(l: &Csr, b: &[f64], x: &mut [f64]) {
    x.copy_from_slice(b);
    for r in (0..l.nrows).rev() {
        let (cols, vals) = l.row(r);
        let xr = x[r] / vals[cols.len() - 1];
        x[r] = xr;
        for k in 0..cols.len() - 1 {
            x[cols[k]] -= vals[k] * xr;
        }
    }
}

/// Forward sweep `L y = b` over supernodal panels (in place on `x`,
/// which enters holding the permuted rhs).  Panel `s` is row-major
/// `m x w` at `panels[panel_ptr[s]..]`; its first `w` rows are the
/// dense lower-triangular diagonal block, the rest scatter into the
/// trailing entries named by `rows`.
///
/// Allocation-free: the warm solve path (`CachedFactor::solve_into`)
/// runs through here under the repo's no_alloc pin.
// rsla-lint: no_alloc
// rsla-lint: allow_item(L1, panel offsets and row indices were sized and bounds-established by the supernodal symbolic pass; x is n-long and rows hold permuted indices below n)
pub fn sn_forward_solve(
    sn_ptr: &[usize],
    row_ptr: &[usize],
    rows: &[usize],
    panel_ptr: &[usize],
    panels: &[f64],
    x: &mut [f64],
) {
    let nsuper = sn_ptr.len() - 1;
    for s in 0..nsuper {
        let lo = sn_ptr[s];
        let hi = sn_ptr[s + 1];
        let w = hi - lo;
        let r0 = row_ptr[s];
        let m = row_ptr[s + 1] - r0;
        let p = &panels[panel_ptr[s]..panel_ptr[s] + m * w];
        for c in 0..w {
            let prow = &p[c * w..c * w + w];
            let v = x[lo + c] - panel_dot(&prow[..c], &x[lo..lo + c]);
            x[lo + c] = v / prow[c];
        }
        for k in w..m {
            let prow = &p[k * w..k * w + w];
            let v = panel_dot(prow, &x[lo..hi]);
            x[rows[r0 + k]] -= v;
        }
    }
}

/// Backward sweep `L^T x = y` over supernodal panels (in place on `x`).
/// The off-diagonal contribution per panel accumulates into a stack
/// buffer of [`SN_MAX_WIDTH`] lanes — the analyze-time width clamp is
/// what keeps this warm path allocation-free.
// rsla-lint: no_alloc
// rsla-lint: allow_item(L1, panel offsets and row indices were sized and bounds-established by the supernodal symbolic pass; acc is stack-bounded by the SN_MAX_WIDTH clamp)
pub fn sn_backward_solve(
    sn_ptr: &[usize],
    row_ptr: &[usize],
    rows: &[usize],
    panel_ptr: &[usize],
    panels: &[f64],
    x: &mut [f64],
) {
    let nsuper = sn_ptr.len() - 1;
    for s in (0..nsuper).rev() {
        let lo = sn_ptr[s];
        let hi = sn_ptr[s + 1];
        let w = hi - lo;
        debug_assert!(w <= SN_MAX_WIDTH);
        let r0 = row_ptr[s];
        let m = row_ptr[s + 1] - r0;
        let p = &panels[panel_ptr[s]..panel_ptr[s] + m * w];
        let mut acc = [0.0f64; SN_MAX_WIDTH];
        for k in w..m {
            let prow = &p[k * w..k * w + w];
            let xr = x[rows[r0 + k]];
            for c in 0..w {
                acc[c] += prow[c] * xr;
            }
        }
        for c in (0..w).rev() {
            let mut t = x[lo + c] - acc[c];
            for c2 in c + 1..w {
                t -= p[c2 * w + c] * x[lo + c2];
            }
            x[lo + c] = t / p[c * w + c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util;

    fn lower_example() -> Csr {
        // L = [[2,0,0],[1,3,0],[0,4,5]]
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 3.0);
        coo.push(2, 1, 4.0);
        coo.push(2, 2, 5.0);
        coo.to_csr()
    }

    #[test]
    fn lower() {
        let l = lower_example();
        let b = vec![2.0, 7.0, 18.0];
        let mut x = vec![0.0; 3];
        lower_solve_csr(&l, &b, &mut x);
        assert!(util::max_abs_diff(&x, &[1.0, 2.0, 2.0]) < 1e-14);
    }

    #[test]
    fn upper() {
        // U = L^T = [[2,1,0],[0,3,4],[0,0,5]]
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 1, 3.0);
        coo.push(1, 2, 4.0);
        coo.push(2, 2, 5.0);
        let u = coo.to_csr();
        let b = vec![4.0, 14.0, 10.0];
        let mut x = vec![0.0; 3];
        upper_solve_csr(&u, &b, &mut x);
        assert!(util::max_abs_diff(&x, &[1.0, 2.0, 2.0]) < 1e-14);
    }

    #[test]
    fn lower_transpose_matches_upper() {
        let l = lower_example();
        let b = vec![4.0, 14.0, 10.0];
        let mut x1 = vec![0.0; 3];
        lower_transpose_solve_csr(&l, &b, &mut x1);
        // L^T x = b should equal solving U x = b with U = L^T
        let u = l.transpose();
        // reorder u rows so diag first: transpose() sorts ascending, diag IS first for upper
        let mut x2 = vec![0.0; 3];
        upper_solve_csr(&u, &b, &mut x2);
        assert!(util::max_abs_diff(&x1, &x2) < 1e-14);
    }
}
