//! Sparse triangular solves on CSR factors (used by the ILU/IC
//! preconditioners, which store their factors as CSR).

use crate::sparse::Csr;

/// Solve L x = b where `l` is lower triangular CSR with the diagonal
/// stored as the LAST entry of each row.
pub fn lower_solve_csr(l: &Csr, b: &[f64], x: &mut [f64]) {
    debug_assert_eq!(l.nrows, b.len());
    for r in 0..l.nrows {
        let (cols, vals) = l.row(r);
        debug_assert!(!cols.is_empty() && cols[cols.len() - 1] == r, "diag last");
        let mut s = b[r];
        for k in 0..cols.len() - 1 {
            s -= vals[k] * x[cols[k]];
        }
        x[r] = s / vals[cols.len() - 1];
    }
}

/// Solve U x = b where `u` is upper triangular CSR with the diagonal
/// stored as the FIRST entry of each row.
pub fn upper_solve_csr(u: &Csr, b: &[f64], x: &mut [f64]) {
    debug_assert_eq!(u.nrows, b.len());
    for r in (0..u.nrows).rev() {
        let (cols, vals) = u.row(r);
        debug_assert!(!cols.is_empty() && cols[0] == r, "diag first");
        let mut s = b[r];
        for k in 1..cols.len() {
            s -= vals[k] * x[cols[k]];
        }
        x[r] = s / vals[0];
    }
}

/// Solve L^T x = b with `l` as in [`lower_solve_csr`] (column sweep).
pub fn lower_transpose_solve_csr(l: &Csr, b: &[f64], x: &mut [f64]) {
    x.copy_from_slice(b);
    for r in (0..l.nrows).rev() {
        let (cols, vals) = l.row(r);
        let xr = x[r] / vals[cols.len() - 1];
        x[r] = xr;
        for k in 0..cols.len() - 1 {
            x[cols[k]] -= vals[k] * xr;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util;

    fn lower_example() -> Csr {
        // L = [[2,0,0],[1,3,0],[0,4,5]]
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(1, 0, 1.0);
        coo.push(1, 1, 3.0);
        coo.push(2, 1, 4.0);
        coo.push(2, 2, 5.0);
        coo.to_csr()
    }

    #[test]
    fn lower() {
        let l = lower_example();
        let b = vec![2.0, 7.0, 18.0];
        let mut x = vec![0.0; 3];
        lower_solve_csr(&l, &b, &mut x);
        assert!(util::max_abs_diff(&x, &[1.0, 2.0, 2.0]) < 1e-14);
    }

    #[test]
    fn upper() {
        // U = L^T = [[2,1,0],[0,3,4],[0,0,5]]
        let mut coo = Coo::new(3, 3);
        coo.push(0, 0, 2.0);
        coo.push(0, 1, 1.0);
        coo.push(1, 1, 3.0);
        coo.push(1, 2, 4.0);
        coo.push(2, 2, 5.0);
        let u = coo.to_csr();
        let b = vec![4.0, 14.0, 10.0];
        let mut x = vec![0.0; 3];
        upper_solve_csr(&u, &b, &mut x);
        assert!(util::max_abs_diff(&x, &[1.0, 2.0, 2.0]) < 1e-14);
    }

    #[test]
    fn lower_transpose_matches_upper() {
        let l = lower_example();
        let b = vec![4.0, 14.0, 10.0];
        let mut x1 = vec![0.0; 3];
        lower_transpose_solve_csr(&l, &b, &mut x1);
        // L^T x = b should equal solving U x = b with U = L^T
        let u = l.transpose();
        // reorder u rows so diag first: transpose() sorts ascending, diag IS first for upper
        let mut x2 = vec![0.0; 3];
        upper_solve_csr(&u, &b, &mut x2);
        assert!(util::max_abs_diff(&x1, &x2) < 1e-14);
    }
}
