//! Family-agnostic handles over the split symbolic/numeric direct
//! factorizations — the plumbing layer under [`crate::factor_cache`].
//!
//! [`Symbolic`] is the pattern-reusable half (RCM + envelope + scatter
//! map for Cholesky; pivot order + elimination reach for LU) and
//! [`CachedFactor`] is a ready numeric factorization that serves both
//! the forward solve and the transpose/adjoint solve — the paper's
//! Eq. 3 adjoint reuses the forward factorization instead of
//! refactoring (§3.2.3).

use std::sync::Arc;

use super::{
    CholSymbolic, EnvelopeCholesky, LuPanels, LuSymbolic, SnCholSymbolic, SnCholesky, SparseLu,
    SupernodalOpts,
};
use crate::error::{Error, Result};
use crate::sparse::Csr;
use crate::trace::{self, names as tn};

/// A symbolic analysis, reusable across value assignments on one
/// sparsity pattern.
#[derive(Clone)]
pub enum Symbolic {
    Chol(Arc<CholSymbolic>),
    /// Supernodal Cholesky partition (blocked kernel engaged).
    SnChol(Arc<SnCholSymbolic>),
    Lu(Arc<LuSymbolic>),
    /// LU recording plus a panel plan over it (blocked replay engaged).
    SnLu {
        sym: Arc<LuSymbolic>,
        plan: Arc<LuPanels>,
    },
}

impl Symbolic {
    /// Bytes held by the symbolic structure.
    pub fn bytes(&self) -> u64 {
        match self {
            Symbolic::Chol(s) => s.bytes(),
            Symbolic::SnChol(s) => s.bytes(),
            Symbolic::Lu(s) => s.bytes(),
            Symbolic::SnLu { sym, plan } => sym.bytes() + plan.bytes(),
        }
    }
}

enum FactorKind {
    Chol(EnvelopeCholesky),
    SnChol(SnCholesky),
    Lu(SparseLu),
}

/// A numeric factorization plus the facts the adjoint path needs, so a
/// single factorization serves forward, repeated, and transpose solves
/// without re-checking anything O(nnz).
pub struct CachedFactor {
    kind: FactorKind,
    /// Numeric symmetry of the factored matrix (cached: kills the
    /// per-backward `is_symmetric` scan).
    pub symmetric: bool,
}

impl CachedFactor {
    pub fn n(&self) -> usize {
        match &self.kind {
            FactorKind::Chol(f) => f.n(),
            FactorKind::SnChol(f) => f.n(),
            FactorKind::Lu(f) => f.n(),
        }
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.n() {
            return Err(Error::InvalidProblem(format!(
                "rhs length {} != n {}",
                b.len(),
                self.n()
            )));
        }
        crate::metrics::mem::note_factor_solve_alloc((self.n() * 8) as u64);
        let _sp = trace::span_arg(tn::DIRECT_TRISOLVE, self.n() as u64);
        match &self.kind {
            FactorKind::Chol(f) => Ok(f.solve(b)),
            FactorKind::SnChol(f) => f.solve(b),
            FactorKind::Lu(f) => f.solve(b),
        }
    }

    /// Allocation-free solve: writes A^{-1} b into `out`, using
    /// `scratch` (grown to length n on first use) as sweep workspace.
    /// Bitwise-identical results to [`CachedFactor::solve`] — both
    /// families run the same floating-point operation sequence — but no
    /// per-call `Vec` is returned, so per-Krylov-iteration callers
    /// (`BlockDirect`, AMG's coarse correction) stop allocating on the
    /// hot path.
    // rsla-lint: no_alloc
    pub fn solve_into(&self, b: &[f64], out: &mut [f64], scratch: &mut Vec<f64>) -> Result<()> {
        let n = self.n();
        if b.len() != n || out.len() != n {
            // rsla-lint: allow(L5, cold error path; allocates only when rejecting bad input)
            return Err(Error::InvalidProblem(format!(
                "rhs length {} != n {}",
                b.len(),
                n
            )));
        }
        if scratch.len() != n {
            scratch.resize(n, 0.0);
        }
        match &self.kind {
            FactorKind::Chol(f) => {
                f.solve_into(b, out, scratch);
                Ok(())
            }
            FactorKind::SnChol(f) => {
                f.solve_into(b, out, scratch);
                Ok(())
            }
            FactorKind::Lu(f) => f.solve_into(b, out, scratch),
        }
    }

    /// Solve A^T x = b from the same factorization (Cholesky: A = A^T;
    /// LU: U^T L^T P forward/backward sweeps).
    pub fn solve_t(&self, b: &[f64]) -> Result<Vec<f64>> {
        if b.len() != self.n() {
            return Err(Error::InvalidProblem(format!(
                "rhs length {} != n {}",
                b.len(),
                self.n()
            )));
        }
        crate::metrics::mem::note_factor_solve_alloc((self.n() * 8) as u64);
        let _sp = trace::span_arg(tn::DIRECT_TRISOLVE, self.n() as u64);
        match &self.kind {
            FactorKind::Chol(f) => Ok(f.solve(b)),
            FactorKind::SnChol(f) => f.solve(b),
            FactorKind::Lu(f) => f.solve_t(b),
        }
    }

    /// Factor bytes held (for memory accounting).
    pub fn bytes(&self) -> u64 {
        match &self.kind {
            FactorKind::Chol(f) => f.bytes(),
            FactorKind::SnChol(f) => f.bytes(),
            FactorKind::Lu(f) => f.bytes(),
        }
    }

    /// The exact quantity the cold-path budget checks compare against
    /// `max_fill_bytes` (Cholesky: predicted fill * 8; LU: stored
    /// entries * 16, excluding the implicit unit diagonal).  Warm-path
    /// budget re-checks MUST use this — not [`CachedFactor::bytes`] —
    /// so a repeated identical request never flips between success and
    /// OutOfMemory with cache warmth.
    pub fn fill_bytes(&self) -> u64 {
        match &self.kind {
            FactorKind::Chol(f) => (f.fill() * 8) as u64,
            FactorKind::SnChol(f) => (f.fill() * 8) as u64,
            FactorKind::Lu(f) => ((f.fill() - f.n()) * 16) as u64,
        }
    }

    /// Method label for solve outcomes.
    pub fn method(&self) -> &'static str {
        match &self.kind {
            FactorKind::Chol(_) => "cholesky+rcm",
            FactorKind::SnChol(_) => "cholesky+rcm+sn",
            FactorKind::Lu(_) => "lu",
        }
    }
}

fn lu_cap(max_fill_bytes: u64) -> usize {
    if max_fill_bytes == u64::MAX {
        usize::MAX
    } else {
        (max_fill_bytes / 16).min(usize::MAX as u64) as usize
    }
}

/// Cold factorization: Cholesky+RCM when the matrix is SPD-looking
/// (symmetric with positive diagonal), LU otherwise, with LU fallback on
/// Cholesky breakdown — the same family policy as `direct_solve` /
/// `native-direct`.  Returns the numeric factor together with its
/// symbolic half for later values-only refactorization.
///
/// `symmetric` is the (already computed) numeric symmetry of `a`;
/// `max_fill_bytes` bounds factor storage ([`Error::OutOfMemory`] when
/// exceeded).
pub fn build_factor(
    a: &Csr,
    symmetric: bool,
    max_fill_bytes: u64,
) -> Result<(Arc<CachedFactor>, Symbolic)> {
    let spd_like = symmetric && a.diag().iter().all(|&d| d > 0.0);
    if spd_like {
        // Supernodal analysis first: pattern-only, so its engage/fallback
        // verdict is identical cold and warm.  Wide enough panels take
        // the blocked kernel; otherwise the envelope kernel below.
        let snsym = {
            let _sp = trace::span_arg(tn::DIRECT_SYMBOLIC, a.nnz() as u64);
            SnCholSymbolic::analyze(a, true, &SupernodalOpts::default())?
        };
        if snsym.engaged() {
            let fill_bytes = (snsym.predicted_fill() * 8) as u64;
            if fill_bytes > max_fill_bytes {
                return Err(Error::OutOfMemory {
                    needed_bytes: fill_bytes,
                    budget_bytes: max_fill_bytes,
                });
            }
            let snsym = Arc::new(snsym);
            let numeric = {
                let _sp = trace::span_arg(tn::DIRECT_NUMERIC, snsym.predicted_fill() as u64);
                SnCholesky::factor_numeric(&snsym, &a.vals)
            };
            match numeric {
                Ok(f) => {
                    return Ok((
                        Arc::new(CachedFactor {
                            kind: FactorKind::SnChol(f),
                            symmetric,
                        }),
                        Symbolic::SnChol(snsym),
                    ));
                }
                Err(Error::Breakdown { .. }) => { /* indefinite: fall through to LU */ }
                Err(e) => return Err(e),
            }
        } else {
            // Sub-threshold panels: the scalar envelope kernel is at
            // least as fast, and the engage verdict is pattern-only so
            // warm refactors of this pattern land here too.
            let sym = {
                let _sp = trace::span_arg(tn::DIRECT_SYMBOLIC, a.nnz() as u64);
                CholSymbolic::analyze(a, true)?
            };
            let fill_bytes = (sym.predicted_fill() * 8) as u64;
            if fill_bytes > max_fill_bytes {
                return Err(Error::OutOfMemory {
                    needed_bytes: fill_bytes,
                    budget_bytes: max_fill_bytes,
                });
            }
            let numeric = {
                let _sp = trace::span_arg(tn::DIRECT_NUMERIC, sym.predicted_fill() as u64);
                EnvelopeCholesky::factor_numeric(&sym, &a.vals)
            };
            match numeric {
                Ok(f) => {
                    return Ok((
                        Arc::new(CachedFactor {
                            kind: FactorKind::Chol(f),
                            symmetric,
                        }),
                        Symbolic::Chol(Arc::new(sym)),
                    ));
                }
                Err(Error::Breakdown { .. }) => { /* indefinite: fall through to LU */ }
                Err(e) => return Err(e),
            }
        }
    }
    // LU records its elimination structure while factoring, so the
    // symbolic and numeric laps are one pass here: one span each, with
    // the symbolic lap carrying zero width at the numeric lap's start.
    let (f, sym) = {
        let _sym_sp = trace::span_arg(tn::DIRECT_SYMBOLIC, a.nnz() as u64);
        let _num_sp = trace::span_arg(tn::DIRECT_NUMERIC, a.nnz() as u64);
        SparseLu::factor_recording(a, lu_cap(max_fill_bytes))?
    };
    // Panel-plan the recorded pivot structure; when the plan is wide
    // enough, the cached factor is rebuilt through the blocked replay so
    // cold and warm numerics share one floating-point schedule.
    let plan = {
        let _sp = trace::span_arg(tn::DIRECT_SYMBOLIC, a.nnz() as u64);
        LuPanels::plan(&sym, &SupernodalOpts::default())
    };
    if plan.engaged() {
        let sym = Arc::new(sym);
        let plan = Arc::new(plan);
        let blocked = {
            let _sp = trace::span_arg(tn::DIRECT_NUMERIC, a.nnz() as u64);
            SparseLu::refactor_blocked(&sym, &plan, a, lu_cap(max_fill_bytes))
        };
        return match blocked {
            Ok(fb) => Ok((
                Arc::new(CachedFactor {
                    kind: FactorKind::Lu(fb),
                    symmetric,
                }),
                Symbolic::SnLu { sym, plan },
            )),
            // Blocked replay refused the recorded pivots (degraded
            // pivot guard): keep the recording factor and a plain
            // symbolic so warm refactors take the column replay.
            Err(_) => Ok((
                Arc::new(CachedFactor {
                    kind: FactorKind::Lu(f),
                    symmetric,
                }),
                Symbolic::Lu(sym),
            )),
        };
    }
    Ok((
        Arc::new(CachedFactor {
            kind: FactorKind::Lu(f),
            symmetric,
        }),
        Symbolic::Lu(Arc::new(sym)),
    ))
}

/// Values-only refactorization against a cached symbolic analysis.
///
/// Fails with [`Error::Breakdown`] when the cached family no longer
/// fits the values (asymmetric values on a Cholesky pattern, vanished
/// LU pivot) — callers fall back to [`build_factor`].
pub fn refactor(
    sym: &Symbolic,
    a: &Csr,
    symmetric: bool,
    max_fill_bytes: u64,
) -> Result<Arc<CachedFactor>> {
    match sym {
        Symbolic::Chol(cs) => {
            if !symmetric {
                return Err(Error::Breakdown {
                    at: 0,
                    reason: "cached Cholesky symbolic, but new values are not symmetric".into(),
                });
            }
            let fill_bytes = (cs.predicted_fill() * 8) as u64;
            if fill_bytes > max_fill_bytes {
                return Err(Error::OutOfMemory {
                    needed_bytes: fill_bytes,
                    budget_bytes: max_fill_bytes,
                });
            }
            let f = {
                let _sp = trace::span_arg(tn::DIRECT_NUMERIC, cs.predicted_fill() as u64);
                EnvelopeCholesky::factor_numeric(cs, &a.vals)?
            };
            Ok(Arc::new(CachedFactor {
                kind: FactorKind::Chol(f),
                symmetric,
            }))
        }
        Symbolic::SnChol(cs) => {
            if !symmetric {
                return Err(Error::Breakdown {
                    at: 0,
                    reason: "cached Cholesky symbolic, but new values are not symmetric".into(),
                });
            }
            let fill_bytes = (cs.predicted_fill() * 8) as u64;
            if fill_bytes > max_fill_bytes {
                return Err(Error::OutOfMemory {
                    needed_bytes: fill_bytes,
                    budget_bytes: max_fill_bytes,
                });
            }
            let f = {
                let _sp = trace::span_arg(tn::DIRECT_NUMERIC, cs.predicted_fill() as u64);
                SnCholesky::factor_numeric(cs, &a.vals)?
            };
            Ok(Arc::new(CachedFactor {
                kind: FactorKind::SnChol(f),
                symmetric,
            }))
        }
        Symbolic::Lu(ls) => {
            let f = {
                let _sp = trace::span_arg(tn::DIRECT_NUMERIC, a.nnz() as u64);
                SparseLu::refactor(ls, a, lu_cap(max_fill_bytes))?
            };
            Ok(Arc::new(CachedFactor {
                kind: FactorKind::Lu(f),
                symmetric,
            }))
        }
        Symbolic::SnLu { sym: ls, plan } => {
            let f = {
                let _sp = trace::span_arg(tn::DIRECT_NUMERIC, a.nnz() as u64);
                SparseLu::refactor_blocked(ls, plan, a, lu_cap(max_fill_bytes))?
            };
            Ok(Arc::new(CachedFactor {
                kind: FactorKind::Lu(f),
                symmetric,
            }))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::graphs::{random_nonsymmetric, random_spd};
    use crate::util::{self, Prng};

    #[test]
    fn build_factor_picks_family_and_serves_transpose() {
        let mut rng = Prng::new(1);
        let spd = random_spd(&mut rng, 40, 3, 1.5);
        let (f, sym) = build_factor(&spd, true, u64::MAX).unwrap();
        assert!(f.method().starts_with("cholesky+rcm"), "{}", f.method());
        assert!(matches!(sym, Symbolic::Chol(_) | Symbolic::SnChol(_)));
        let b = rng.normal_vec(40);
        let x = f.solve(&b).unwrap();
        assert!(util::rel_l2(&spd.matvec(&x), &b) < 1e-10);
        // symmetric: transpose solve equals forward solve
        assert_eq!(f.solve_t(&b).unwrap(), x);

        let gen = random_nonsymmetric(&mut rng, 40, 4);
        let (f, sym) = build_factor(&gen, false, u64::MAX).unwrap();
        assert_eq!(f.method(), "lu");
        assert!(matches!(sym, Symbolic::Lu(_) | Symbolic::SnLu { .. }));
        let xt = f.solve_t(&b).unwrap();
        let mut atx = vec![0.0; 40];
        gen.spmv_t(&xt, &mut atx);
        assert!(util::rel_l2(&atx, &b) < 1e-9);
    }

    #[test]
    fn refactor_reuses_symbolic_for_both_families() {
        let mut rng = Prng::new(2);
        let spd = random_spd(&mut rng, 30, 3, 2.0);
        let (_, sym) = build_factor(&spd, true, u64::MAX).unwrap();
        let mut spd2 = spd.clone();
        for v in spd2.vals.iter_mut() {
            *v *= 2.0;
        }
        let f = refactor(&sym, &spd2, true, u64::MAX).unwrap();
        let b = rng.normal_vec(30);
        let x = f.solve(&b).unwrap();
        assert!(util::rel_l2(&spd2.matvec(&x), &b) < 1e-10);

        let gen = random_nonsymmetric(&mut rng, 30, 3);
        let (_, sym) = build_factor(&gen, false, u64::MAX).unwrap();
        let mut gen2 = gen.clone();
        for v in gen2.vals.iter_mut() {
            *v *= 1.1;
        }
        let f = refactor(&sym, &gen2, false, u64::MAX).unwrap();
        let x = f.solve(&b).unwrap();
        assert!(util::rel_l2(&gen2.matvec(&x), &b) < 1e-9);
    }

    #[test]
    fn chol_symbolic_rejects_asymmetric_values() {
        let mut rng = Prng::new(3);
        let spd = random_spd(&mut rng, 20, 3, 2.0);
        let (_, sym) = build_factor(&spd, true, u64::MAX).unwrap();
        let mut bad = spd.clone();
        bad.vals[1] += 0.5; // breaks symmetry
        assert!(matches!(
            refactor(&sym, &bad, false, u64::MAX),
            Err(Error::Breakdown { .. })
        ));
    }

    #[test]
    fn solve_into_bitwise_matches_solve_for_both_families() {
        let mut rng = Prng::new(4);
        let b = rng.normal_vec(35);
        let spd = random_spd(&mut rng, 35, 3, 1.5);
        let gen = random_nonsymmetric(&mut rng, 35, 4);
        for (a, symmetric) in [(&spd, true), (&gen, false)] {
            let (f, _) = build_factor(a, symmetric, u64::MAX).unwrap();
            let x = f.solve(&b).unwrap();
            let mut out = vec![0.0; 35];
            let mut scratch = Vec::new();
            f.solve_into(&b, &mut out, &mut scratch).unwrap();
            assert_eq!(x, out, "solve_into diverged from solve ({})", f.method());
        }
        // shape misuse stays a typed error
        let (f, _) = build_factor(&spd, true, u64::MAX).unwrap();
        let mut short = vec![0.0; 3];
        assert!(f.solve_into(&b, &mut short, &mut Vec::new()).is_err());
    }

    #[test]
    fn budget_propagates_as_oom() {
        use crate::sparse::poisson::poisson2d;
        let sys = poisson2d(24, None);
        assert!(matches!(
            build_factor(&sys.matrix, true, 10_000),
            Err(Error::OutOfMemory { .. })
        ));
    }
}
