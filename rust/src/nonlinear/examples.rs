//! Shared example residuals: the nonlinearities the benches, tests,
//! and CLI demos put on the solvers.  Defined ONCE here so every
//! harness exercises the same F (previously each site carried its own
//! copy of the paper's quadratic Poisson and they could drift).

use super::{KrylovResidual, Residual};
use crate::sparse::{Coo, Csr};

/// The paper's example nonlinearity `F(u) = A u + u^2 - f` (Table 5's
/// nonlinear row): a Poisson-like operator plus a pointwise quadratic,
/// with `theta = f` as the differentiable parameter (`dF/df = -I`).
///
/// Implements both residual interfaces: [`Residual`] (assembled
/// Jacobian `J = A + 2 diag(u)`) for damped Newton and the adjoint
/// framework, and [`KrylovResidual`] (`J v = A v + 2 u .* v`, no
/// assembly) for matrix-free Newton–Krylov.
pub struct QuadPoisson {
    pub a: Csr,
    pub f: Vec<f64>,
}

impl Residual for QuadPoisson {
    fn dim(&self) -> usize {
        self.f.len()
    }

    fn eval(&self, u: &[f64], out: &mut [f64]) {
        self.a.spmv(u, out);
        for i in 0..u.len() {
            out[i] += u[i] * u[i] - self.f[i];
        }
    }

    fn jacobian(&self, u: &[f64]) -> Csr {
        let n = self.a.nrows;
        let mut coo = Coo::with_capacity(n, n, self.a.nnz() + n);
        for r in 0..n {
            let (cols, vals) = self.a.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(r, *c, *v);
            }
            coo.push(r, r, 2.0 * u[r]);
        }
        coo.to_csr()
    }

    fn vjp_theta(&self, _u: &[f64], w: &[f64]) -> Vec<f64> {
        // theta = f and dF/df = -I, so w^T dF/df = -w
        w.iter().map(|x| -x).collect()
    }
}

impl KrylovResidual for QuadPoisson {
    fn n_own(&self) -> usize {
        self.f.len()
    }

    fn eval(&self, u_ext: &mut [f64], out_own: &mut [f64]) {
        Residual::eval(self, u_ext, out_own);
    }

    fn jv(&self, u_ext: &[f64], v_ext: &mut [f64], y_own: &mut [f64]) {
        // J v = A v + 2 u .* v
        self.a.spmv(v_ext, y_own);
        for i in 0..y_own.len() {
            y_own[i] += 2.0 * u_ext[i] * v_ext[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::poisson::poisson2d;
    use crate::util::{norm2, Prng};

    #[test]
    fn assembled_jacobian_matches_matrix_free_jv() {
        let sys = poisson2d(6, None);
        let n = 36;
        let mut rng = Prng::new(8);
        let r = QuadPoisson {
            a: sys.matrix,
            f: vec![1.0; n],
        };
        let u = rng.normal_vec(n);
        let mut v = rng.normal_vec(n);
        let jv_assembled = Residual::jacobian(&r, &u).matvec(&v);
        let mut jv_free = vec![0.0; n];
        KrylovResidual::jv(&r, &u, &mut v, &mut jv_free);
        let diff: Vec<f64> = jv_assembled
            .iter()
            .zip(&jv_free)
            .map(|(a, b)| a - b)
            .collect();
        assert!(norm2(&diff) < 1e-12 * norm2(&jv_assembled).max(1.0));
    }
}
