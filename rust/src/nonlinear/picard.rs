//! Picard (fixed-point) iteration with relaxation: u <- (1-w) u + w G(u).

use super::NonlinearResult;
use crate::util::norm2;

#[derive(Clone, Debug)]
pub struct PicardOpts {
    pub tol: f64,
    pub max_iters: usize,
    /// Relaxation weight in (0, 1].
    pub relax: f64,
}

impl Default for PicardOpts {
    fn default() -> Self {
        PicardOpts {
            tol: 1e-10,
            max_iters: 1000,
            relax: 1.0,
        }
    }
}

/// Solve u = G(u) by relaxed fixed-point iteration.  Convergence is
/// measured on the update norm ||G(u) - u||.
pub fn picard<G>(g: G, u0: &[f64], opts: &PicardOpts) -> NonlinearResult
where
    G: Fn(&[f64], &mut [f64]),
{
    let n = u0.len();
    let mut u = u0.to_vec();
    let mut gu = vec![0.0; n];
    let mut diff = f64::INFINITY;
    let mut iters = 0;
    while iters < opts.max_iters && diff > opts.tol {
        g(&u, &mut gu);
        let mut d2 = 0.0;
        for i in 0..n {
            let step = gu[i] - u[i];
            d2 += step * step;
            u[i] += opts.relax * step;
        }
        diff = d2.sqrt();
        iters += 1;
    }
    let _ = norm2(&u);
    NonlinearResult {
        converged: diff <= opts.tol,
        u,
        iters,
        residual_norm: diff,
        linear_solves: iters, // one G evaluation (typically a solve) per iter
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_cosine_fixed_point() {
        // u = cos(u) -> Dottie number 0.739085...
        let r = picard(
            |u, out| out[0] = u[0].cos(),
            &[0.0],
            &PicardOpts::default(),
        );
        assert!(r.converged);
        assert!((r.u[0] - 0.739_085_133_215_160_6).abs() < 1e-9);
    }

    #[test]
    fn relaxation_tames_divergence() {
        // u = -2u + 3 has fixed point 1 but |G'| = 2 > 1: plain Picard
        // diverges, heavy relaxation converges.
        let plain = picard(
            |u, out| out[0] = -2.0 * u[0] + 3.0,
            &[0.0],
            &PicardOpts {
                max_iters: 60,
                ..PicardOpts::default()
            },
        );
        assert!(!plain.converged);
        let relaxed = picard(
            |u, out| out[0] = -2.0 * u[0] + 3.0,
            &[0.0],
            &PicardOpts {
                relax: 0.25,
                max_iters: 500,
                ..PicardOpts::default()
            },
        );
        assert!(relaxed.converged);
        assert!((relaxed.u[0] - 1.0).abs() < 1e-8);
    }

    #[test]
    fn vector_linear_contraction() {
        // u = 0.5 u + c -> u* = 2c
        let c = [1.0, -2.0, 0.5];
        let r = picard(
            |u, out| {
                for i in 0..3 {
                    out[i] = 0.5 * u[i] + c[i];
                }
            },
            &[0.0; 3],
            &PicardOpts::default(),
        );
        assert!(r.converged);
        for i in 0..3 {
            assert!((r.u[i] - 2.0 * c[i]).abs() < 1e-8);
        }
    }
}
