//! Anderson acceleration (Anderson 1965) for fixed-point iterations,
//! type-II (least-squares on residual differences) with Tikhonov-
//! regularized normal equations.

use super::{NonlinearResult, PicardOpts};
use crate::util::norm2;

/// Solve u = G(u) with Anderson depth `m` (m = 0 degenerates to Picard).
pub fn anderson<G>(g: G, u0: &[f64], m: usize, opts: &PicardOpts) -> NonlinearResult
where
    G: Fn(&[f64], &mut [f64]),
{
    let n = u0.len();
    let beta = opts.relax;
    let mut u = u0.to_vec();
    let mut gu = vec![0.0; n];

    // histories of u_k and f_k = G(u_k) - u_k
    let mut us: Vec<Vec<f64>> = Vec::new();
    let mut fs: Vec<Vec<f64>> = Vec::new();

    let mut iters = 0;
    let mut fnorm = f64::INFINITY;
    while iters < opts.max_iters && fnorm > opts.tol {
        g(&u, &mut gu);
        let f: Vec<f64> = (0..n).map(|i| gu[i] - u[i]).collect();
        fnorm = norm2(&f);
        if fnorm <= opts.tol {
            u = gu.clone();
            iters += 1;
            break;
        }
        us.push(u.clone());
        fs.push(f.clone());
        if us.len() > m + 1 {
            us.remove(0);
            fs.remove(0);
        }
        let mk = us.len() - 1;
        if mk == 0 {
            // plain relaxed Picard step
            for i in 0..n {
                u[i] += beta * f[i];
            }
        } else {
            // df_j = f_{j+1} - f_j, du_j = u_{j+1} - u_j (j = 0..mk)
            let mut dftf = vec![0f64; mk * mk];
            let mut dff = vec![0f64; mk];
            let df: Vec<Vec<f64>> = (0..mk)
                .map(|j| (0..n).map(|i| fs[j + 1][i] - fs[j][i]).collect())
                .collect();
            for a in 0..mk {
                for b in a..mk {
                    let v = crate::util::dot(&df[a], &df[b]);
                    dftf[a * mk + b] = v;
                    dftf[b * mk + a] = v;
                }
                dff[a] = crate::util::dot(&df[a], &f);
            }
            // Tikhonov regularization for near-singular histories
            let trace: f64 = (0..mk).map(|a| dftf[a * mk + a]).sum();
            let reg = 1e-12 * (trace / mk as f64).max(1e-300);
            for a in 0..mk {
                dftf[a * mk + a] += reg;
            }
            let gamma = dense_solve(&mut dftf, &mut dff, mk);
            // u_{k+1} = u_k + beta f_k - sum_j gamma_j (du_j + beta df_j)
            let mut unew: Vec<f64> = (0..n).map(|i| u[i] + beta * f[i]).collect();
            for j in 0..mk {
                let gj = gamma[j];
                if gj == 0.0 {
                    continue;
                }
                for i in 0..n {
                    let du_ji = us[j + 1][i] - us[j][i];
                    unew[i] -= gj * (du_ji + beta * df[j][i]);
                }
            }
            u = unew;
        }
        iters += 1;
    }

    NonlinearResult {
        converged: fnorm <= opts.tol,
        u,
        iters,
        residual_norm: fnorm,
        linear_solves: iters,
    }
}

/// In-place dense Gaussian elimination with partial pivoting (tiny
/// systems from the Anderson normal equations).
fn dense_solve(a: &mut [f64], b: &mut [f64], n: usize) -> Vec<f64> {
    for col in 0..n {
        // pivot
        let mut piv = col;
        for r in col + 1..n {
            if a[r * n + col].abs() > a[piv * n + col].abs() {
                piv = r;
            }
        }
        if piv != col {
            for c in 0..n {
                a.swap(col * n + c, piv * n + c);
            }
            b.swap(col, piv);
        }
        let d = a[col * n + col];
        if d == 0.0 {
            continue; // singular direction: leave gamma 0
        }
        for r in col + 1..n {
            let factor = a[r * n + col] / d;
            if factor == 0.0 {
                continue;
            }
            for c in col..n {
                a[r * n + c] -= factor * a[col * n + c];
            }
            b[r] -= factor * b[col];
        }
    }
    let mut x = vec![0f64; n];
    for r in (0..n).rev() {
        let mut s = b[r];
        for c in r + 1..n {
            s -= a[r * n + c] * x[c];
        }
        let d = a[r * n + r];
        x[r] = if d != 0.0 { s / d } else { 0.0 };
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nonlinear::picard;

    #[test]
    fn accelerates_cosine_fixed_point() {
        let opts = PicardOpts {
            tol: 1e-12,
            max_iters: 200,
            relax: 1.0,
        };
        let pic = picard(|u, out| out[0] = u[0].cos(), &[0.0], &opts);
        let and = anderson(|u, out| out[0] = u[0].cos(), &[0.0], 3, &opts);
        assert!(pic.converged && and.converged);
        assert!(
            and.iters < pic.iters / 2,
            "anderson {} vs picard {}",
            and.iters,
            pic.iters
        );
        assert!((and.u[0] - 0.739_085_133_215_160_6).abs() < 1e-9);
    }

    #[test]
    fn handles_linear_vector_map() {
        // u = M u + c with spectral radius < 1
        let mmat = [[0.5, 0.1], [0.0, 0.3]];
        let c = [1.0, 2.0];
        let gmap = |u: &[f64], out: &mut [f64]| {
            for i in 0..2 {
                out[i] = mmat[i][0] * u[0] + mmat[i][1] * u[1] + c[i];
            }
        };
        let r = anderson(gmap, &[0.0, 0.0], 2, &PicardOpts::default());
        assert!(r.converged);
        // exact: (I - M) u = c
        let u1 = 2.0 / 0.7;
        let u0 = (1.0 + 0.1 * u1) / 0.5;
        assert!((r.u[0] - u0).abs() < 1e-8);
        assert!((r.u[1] - u1).abs() < 1e-8);
        // Anderson with depth >= dimension converges in O(dim) iterations
        // on affine maps; allow slack for the regularization
        assert!(r.iters <= 10, "iters {}", r.iters);
    }

    #[test]
    fn dense_solve_small() {
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        let x = dense_solve(&mut a, &mut b, 2);
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }
}
