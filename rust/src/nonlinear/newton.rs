//! Damped Newton with assembled-Jacobian direct steps, and matrix-free
//! Newton–Krylov over the unified `LinearOperator x Communicator`
//! substrate.
//!
//! The assembled path: the Jacobian's sparsity pattern is fixed across
//! iterations (only the values move), so each step's linear solve goes
//! through the pattern-keyed factor cache — iteration 1 pays the
//! symbolic analysis, every later iteration runs the numeric
//! refactorization only.
//!
//! The matrix-free path ([`newton_krylov`]): each step solves `J du =
//! -F` with the generic GMRES kernel, applying `J` through
//! [`KrylovResidual::jv`] — no assembly, no factorization, and the SAME
//! body runs serial (via [`SerialResidual`] + `NullComm`) and
//! distributed (halo-exchanged residuals + `LocalComm`), which is the
//! paper's §3.3 composition extended to nonlinear systems.

use super::{KrylovResidual, NonlinearResult, Residual, SerialResidual};
use crate::factor_cache::cached_direct_solve;
use crate::iterative::{Identity, IterOpts};
use crate::krylov::{self, gdot, Communicator, LinearOperator, NullComm};
use crate::util::norm2;

#[derive(Clone, Debug)]
pub struct NewtonOpts {
    pub tol: f64,
    pub max_iters: usize,
    /// Armijo-style backtracking halvings per step (0 = undamped).
    pub max_halvings: usize,
    /// Force exactly `max_iters` iterations (Table 5 uses fixed 5 Newton
    /// steps to count forward cost).
    pub fixed_iters: bool,
}

impl Default for NewtonOpts {
    fn default() -> Self {
        NewtonOpts {
            tol: 1e-10,
            max_iters: 50,
            max_halvings: 20,
            fixed_iters: false,
        }
    }
}

/// What one Newton instantiation must provide to the shared outer
/// driver: a residual evaluation, a (global) norm, and a step solver.
/// Both the assembled-Jacobian and matrix-free Newton–Krylov paths are
/// instantiations of [`damped_newton`] over this trait, so the outer
/// control flows CANNOT diverge — there is only one (pinned bitwise by
/// `tests/newton_equivalence.rs` against the frozen pre-refactor
/// loops).
trait NewtonFlow {
    /// Entries owned by this rank (serial: the full dimension).
    fn n_own(&self) -> usize;

    /// Extended workspace length (owned + halo); `n_own` for serial.
    fn n_ext(&self) -> usize {
        self.n_own()
    }

    /// `out = F(u)` on owned rows; may refresh `u_ext`'s halo tail.
    fn eval(&mut self, u_ext: &mut [f64], out_own: &mut [f64]);

    /// Globally-reduced Euclidean norm of an owned vector.
    fn norm(&mut self, v: &[f64]) -> f64;

    /// Solve the Newton step `J(u) du = rhs`.  `None` signals a
    /// degenerate step (singular Jacobian, non-finite Krylov iterate);
    /// the driver returns the best iterate so far.  Implementations
    /// with a rank team must make the degeneracy decision GLOBAL so
    /// control flow cannot desynchronize across ranks.
    fn solve_step(&mut self, u_ext: &[f64], rhs: &[f64]) -> Option<Vec<f64>>;

    /// Whether a degenerate `solve_step` still consumed a linear solve.
    /// The matrix-free flow runs GMRES BEFORE it can see the non-finite
    /// iterate, so its failed step counts (matching the pre-refactor
    /// `newton_krylov`); a failed direct factorization never reached a
    /// solve, so the assembled flow's does not (matching `newton`).
    fn failed_step_counts(&self) -> bool {
        false
    }
}

/// The ONE damped-Newton outer loop: residual evaluation, step solve,
/// Armijo-style backtracking on the (global) ||F||, full-step fallback,
/// fixed-iteration mode.  Works on the extended (owned + halo) layout;
/// serial instantiations have an empty halo tail.
fn damped_newton<F: NewtonFlow>(
    flow: &mut F,
    u0_own: &[f64],
    opts: &NewtonOpts,
) -> NonlinearResult {
    let n = flow.n_own();
    assert_eq!(u0_own.len(), n);
    let n_ext = flow.n_ext();
    let mut u_ext = vec![0.0; n_ext];
    u_ext[..n].copy_from_slice(u0_own);
    let mut fu = vec![0.0; n];
    flow.eval(&mut u_ext, &mut fu);
    let mut fnorm = flow.norm(&fu);
    let mut linear_solves = 0;
    let mut trial_ext = vec![0.0; n_ext];

    let mut iters = 0;
    while iters < opts.max_iters && (opts.fixed_iters || fnorm > opts.tol) {
        // Newton step: J du = -F
        let rhs: Vec<f64> = fu.iter().map(|x| -x).collect();
        let du = match flow.solve_step(&u_ext, &rhs) {
            Some(du) => du,
            None => {
                // degenerate Jacobian: return best iterate
                if flow.failed_step_counts() {
                    linear_solves += 1;
                }
                break;
            }
        };
        linear_solves += 1;
        // backtracking line search on the (global) ||F||
        let mut t = 1.0;
        let mut accepted = false;
        for _ in 0..=opts.max_halvings {
            for i in 0..n {
                trial_ext[i] = u_ext[i] + t * du[i];
            }
            let mut ftrial = vec![0.0; n];
            flow.eval(&mut trial_ext, &mut ftrial);
            let fn_trial = flow.norm(&ftrial);
            if fn_trial < fnorm || opts.max_halvings == 0 {
                // full extended copy: the eval above refreshed
                // trial_ext's halo, and the next step solve is promised
                // a CURRENT halo on u_ext
                u_ext.copy_from_slice(&trial_ext);
                fu = ftrial;
                fnorm = fn_trial;
                accepted = true;
                break;
            }
            t *= 0.5;
        }
        if !accepted {
            // full step as a last resort (keeps fixed_iters semantics)
            for i in 0..n {
                u_ext[i] += du[i];
            }
            flow.eval(&mut u_ext, &mut fu);
            fnorm = flow.norm(&fu);
        }
        iters += 1;
    }

    NonlinearResult {
        converged: fnorm <= opts.tol,
        u: u_ext[..n].to_vec(),
        iters,
        residual_norm: fnorm,
        linear_solves,
    }
}

/// Assembled-Jacobian instantiation: serial layout, `norm2`, and a
/// pluggable linear step solver over the assembled `J`.
struct AssembledFlow<'a> {
    f: &'a dyn Residual,
    step: &'a mut dyn FnMut(&crate::sparse::Csr, &[f64]) -> Option<Vec<f64>>,
}

impl NewtonFlow for AssembledFlow<'_> {
    fn n_own(&self) -> usize {
        self.f.dim()
    }

    fn eval(&mut self, u_ext: &mut [f64], out_own: &mut [f64]) {
        self.f.eval(u_ext, out_own);
    }

    fn norm(&mut self, v: &[f64]) -> f64 {
        norm2(v)
    }

    fn solve_step(&mut self, u_ext: &[f64], rhs: &[f64]) -> Option<Vec<f64>> {
        let j = self.f.jacobian(u_ext);
        (self.step)(&j, rhs)
    }
}

/// Solve F(u) = 0 by damped Newton from `u0`, each step solved through
/// the pattern-keyed factor cache (iteration 1 pays the symbolic
/// analysis; later iterations refactor numerically only).
pub fn newton(f: &dyn Residual, u0: &[f64], opts: &NewtonOpts) -> NonlinearResult {
    let mut step =
        |j: &crate::sparse::Csr, rhs: &[f64]| cached_direct_solve(j, rhs).ok();
    newton_with_step(f, u0, opts, &mut step)
}

/// Damped Newton over a caller-supplied step solver (`None` = singular
/// Jacobian, return best iterate).  The engine's workers pass a
/// shard-local factor-cache solve here so Newton jobs inherit
/// pattern-affinity warmth; `newton` itself is the process-wide-cache
/// instantiation.
pub fn newton_with_step(
    f: &dyn Residual,
    u0: &[f64],
    opts: &NewtonOpts,
    step: &mut dyn FnMut(&crate::sparse::Csr, &[f64]) -> Option<Vec<f64>>,
) -> NonlinearResult {
    let mut flow = AssembledFlow { f, step };
    damped_newton(&mut flow, u0, opts)
}

/// The matrix-free Jacobian as a [`LinearOperator`]: `J(u) v` through
/// [`KrylovResidual::jv`], halo handled by the residual implementation.
struct JvOp<'a> {
    f: &'a dyn KrylovResidual,
    u_ext: &'a [f64],
}

impl LinearOperator for JvOp<'_> {
    fn n_own(&self) -> usize {
        self.f.n_own()
    }

    fn n_ext(&self) -> usize {
        self.f.n_ext()
    }

    fn apply(&self, x_ext: &mut [f64], y_own: &mut [f64]) {
        self.f.jv(self.u_ext, x_ext, y_own);
    }
}

/// Matrix-free instantiation: extended (owned + halo) layout, global
/// norms via `comm`, GMRES step through JVPs, with the degenerate-step
/// decision made GLOBALLY (a NaN on one rank with divergent control
/// flow would deadlock the team).
struct KrylovFlow<'a> {
    f: &'a dyn KrylovResidual,
    comm: &'a dyn Communicator,
    inner: &'a IterOpts,
}

impl NewtonFlow for KrylovFlow<'_> {
    fn n_own(&self) -> usize {
        self.f.n_own()
    }

    fn n_ext(&self) -> usize {
        self.f.n_ext()
    }

    fn eval(&mut self, u_ext: &mut [f64], out_own: &mut [f64]) {
        self.f.eval(u_ext, out_own);
    }

    fn norm(&mut self, v: &[f64]) -> f64 {
        gdot(self.comm, v, v).sqrt()
    }

    fn solve_step(&mut self, u_ext: &[f64], rhs: &[f64]) -> Option<Vec<f64>> {
        // matrix-free GMRES (the Jacobian is nonsymmetric in general)
        let res = {
            let jop = JvOp { f: self.f, u_ext };
            krylov::gmres(&jop, rhs, &Identity, 50, self.comm, self.inner, None)
        };
        let du = res.x;
        let local_bad = if du.iter().any(|d| !d.is_finite()) { 1.0 } else { 0.0 };
        if self.comm.all_reduce_sum(local_bad) > 0.0 {
            None
        } else {
            Some(du)
        }
    }

    fn failed_step_counts(&self) -> bool {
        true // GMRES ran before the finiteness check
    }
}

/// Matrix-free (Jacobian-free) Newton–Krylov: solve `F(u) = 0` from
/// `u0_own`, each step solved by the generic GMRES kernel applying `J`
/// through JVPs.  `comm` makes the same body serial ([`NullComm`]) or
/// distributed (`LocalComm`); all norms and inner products are global.
pub fn newton_krylov(
    f: &dyn KrylovResidual,
    u0_own: &[f64],
    comm: &dyn Communicator,
    opts: &NewtonOpts,
    inner: &IterOpts,
) -> NonlinearResult {
    let mut flow = KrylovFlow { f, comm, inner };
    damped_newton(&mut flow, u0_own, opts)
}

/// Serial convenience wrapper: matrix-free Newton–Krylov on any
/// [`Residual`] via its JVP, under [`NullComm`].
pub fn newton_krylov_serial(
    f: &dyn Residual,
    u0: &[f64],
    opts: &NewtonOpts,
    inner: &IterOpts,
) -> NonlinearResult {
    newton_krylov(&SerialResidual(f), u0, &NullComm, opts, inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nonlinear::test_residuals::QuadraticPoisson;
    use crate::sparse::poisson::poisson2d;
    use crate::util::Prng;

    fn problem(g: usize, seed: u64) -> QuadraticPoisson {
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(seed);
        let f: Vec<f64> = (0..g * g).map(|_| rng.uniform() + 0.5).collect();
        QuadraticPoisson { sys, f }
    }

    #[test]
    fn converges_quadratically() {
        let p = problem(10, 0);
        let r = newton(&p, &vec![0.0; 100], &NewtonOpts::default());
        assert!(r.converged, "residual {}", r.residual_norm);
        assert!(r.iters <= 10, "took {} iterations", r.iters);
        // verify F(u) ~ 0
        let mut fu = vec![0.0; 100];
        p.eval(&r.u, &mut fu);
        assert!(crate::util::norm2(&fu) < 1e-9);
    }

    #[test]
    fn fixed_iteration_mode_runs_exactly_k() {
        let p = problem(8, 1);
        let r = newton(
            &p,
            &vec![0.0; 64],
            &NewtonOpts {
                max_iters: 5,
                fixed_iters: true,
                ..NewtonOpts::default()
            },
        );
        assert_eq!(r.iters, 5);
        assert_eq!(r.linear_solves, 5);
    }

    #[test]
    fn newton_krylov_matches_assembled_newton() {
        // matrix-free NK (FD-JVP + generic GMRES under NullComm) must
        // find the same root as assembled-Jacobian direct Newton
        let p = problem(10, 4);
        let direct = newton(&p, &vec![0.0; 100], &NewtonOpts::default());
        let nk = newton_krylov_serial(
            &p,
            &vec![0.0; 100],
            &NewtonOpts::default(),
            &IterOpts {
                tol: 1e-9,
                max_iters: 500,
                record_history: false,
            },
        );
        assert!(direct.converged && nk.converged, "nk residual {}", nk.residual_norm);
        assert!(crate::util::max_abs_diff(&nk.u, &direct.u) < 1e-7);
        assert_eq!(nk.linear_solves, nk.iters);
    }

    #[test]
    fn jvp_default_matches_jacobian() {
        let p = problem(6, 2);
        let mut rng = Prng::new(3);
        let u = rng.normal_vec(36);
        let v = rng.normal_vec(36);
        let mut jv_fd = vec![0.0; 36];
        crate::nonlinear::Residual::jvp(&p, &u, &v, &mut jv_fd);
        let j = p.jacobian(&u);
        let jv = j.matvec(&v);
        for i in 0..36 {
            assert!(
                (jv_fd[i] - jv[i]).abs() < 1e-5 * (1.0 + jv[i].abs()),
                "i={i}: {} vs {}",
                jv_fd[i],
                jv[i]
            );
        }
    }

    #[test]
    fn scalar_quadratic() {
        // F(u) = u^2 - 4 via a custom residual; root at 2
        struct Sq;
        impl crate::nonlinear::Residual for Sq {
            fn dim(&self) -> usize {
                1
            }
            fn eval(&self, u: &[f64], out: &mut [f64]) {
                out[0] = u[0] * u[0] - 4.0;
            }
            fn jacobian(&self, u: &[f64]) -> crate::sparse::Csr {
                let mut coo = crate::sparse::Coo::new(1, 1);
                coo.push(0, 0, 2.0 * u[0]);
                coo.to_csr()
            }
        }
        let r = newton(&Sq, &[1.0], &NewtonOpts::default());
        assert!(r.converged);
        assert!((r.u[0] - 2.0).abs() < 1e-10);
    }
}
