//! Nonlinear solvers: Newton, Picard, and Anderson acceleration
//! (paper §3.2.2, "Nonlinear systems").
//!
//! Residuals implement [`Residual`]; the assembled-Jacobian path powers
//! damped Newton (each step solved by the direct/iterative substrate),
//! and the JVP/VJP hooks power matrix-free Newton–Krylov and — crucially
//! — the adjoint solve `J^T lambda = dL/du` in [`crate::adjoint`].

pub mod anderson;
pub mod examples;
pub mod newton;
pub mod picard;

pub use anderson::anderson;
pub use newton::{newton, newton_krylov, newton_krylov_serial, newton_with_step, NewtonOpts};
pub use picard::{picard, PicardOpts};

use crate::sparse::Csr;

/// Rank-local view of a nonlinear residual for matrix-free
/// Newton–Krylov over the unified substrate: the residual is evaluated
/// on owned rows and the Jacobian is *applied*, never assembled, in the
/// same extended (owned + halo) layout the [`crate::krylov`] kernels
/// use.  Serial residuals get this view through [`SerialResidual`];
/// `distributed::DistPointwiseResidual` is the halo-exchanged
/// implementation.
pub trait KrylovResidual {
    /// Entries owned by this rank.
    fn n_own(&self) -> usize;

    /// Extended workspace length (owned + halo); `n_own` for serial.
    fn n_ext(&self) -> usize {
        self.n_own()
    }

    /// `out = F(u)` on owned rows.  `u_ext[..n_own]` is current; the
    /// implementation may refresh the halo tail (one exchange).
    fn eval(&self, u_ext: &mut [f64], out_own: &mut [f64]);

    /// `y = J(u) v` on owned rows — matrix-free.  `v_ext`'s halo may be
    /// refreshed; `u_ext`'s halo is current from the last `eval`.
    fn jv(&self, u_ext: &[f64], v_ext: &mut [f64], y_own: &mut [f64]);
}

/// Bridge from any serial [`Residual`] (JVP-capable) to the rank-local
/// [`KrylovResidual`] view.
pub struct SerialResidual<'a>(pub &'a dyn Residual);

impl KrylovResidual for SerialResidual<'_> {
    fn n_own(&self) -> usize {
        self.0.dim()
    }

    fn eval(&self, u_ext: &mut [f64], out_own: &mut [f64]) {
        self.0.eval(u_ext, out_own);
    }

    fn jv(&self, u_ext: &[f64], v_ext: &mut [f64], y_own: &mut [f64]) {
        self.0.jvp(u_ext, v_ext, y_own);
    }
}

/// A nonlinear residual F(u; theta) = 0 with differentiable structure.
///
/// `theta` is carried by the implementing struct; the adjoint layer asks
/// for VJPs against it via [`Residual::vjp_theta`].
pub trait Residual {
    fn dim(&self) -> usize;

    /// out = F(u).
    fn eval(&self, u: &[f64], out: &mut [f64]);

    /// Assembled Jacobian J = dF/du at `u`.
    fn jacobian(&self, u: &[f64]) -> Csr;

    /// Jacobian-vector product J v (default: finite difference).
    fn jvp(&self, u: &[f64], v: &[f64], out: &mut [f64]) {
        let n = self.dim();
        let eps = 1e-7 * (1.0 + crate::util::norm2(u)) / (1.0 + crate::util::norm2(v));
        let mut up = u.to_vec();
        let mut um = u.to_vec();
        for i in 0..n {
            up[i] += eps * v[i];
            um[i] -= eps * v[i];
        }
        let mut fp = vec![0.0; n];
        let mut fm = vec![0.0; n];
        self.eval(&up, &mut fp);
        self.eval(&um, &mut fm);
        for i in 0..n {
            out[i] = (fp[i] - fm[i]) / (2.0 * eps);
        }
    }

    /// Vector-Jacobian product w^T J (default: via assembled Jacobian).
    fn vjp_u(&self, u: &[f64], w: &[f64], out: &mut [f64]) {
        let j = self.jacobian(u);
        j.spmv_t(w, out);
    }

    /// Gradient of w^T F with respect to the residual's parameters theta,
    /// flattened.  Needed by the adjoint framework; the default is "no
    /// parameters".
    fn vjp_theta(&self, _u: &[f64], _w: &[f64]) -> Vec<f64> {
        Vec::new()
    }
}

/// Result of a nonlinear solve.
#[derive(Clone, Debug)]
pub struct NonlinearResult {
    pub u: Vec<f64>,
    pub iters: usize,
    pub residual_norm: f64,
    pub converged: bool,
    /// Number of inner linear solves performed (paper Table 5 reports
    /// forward cost in units of solves).
    pub linear_solves: usize,
}

#[cfg(test)]
pub(crate) mod test_residuals {
    use super::*;
    use crate::sparse::poisson::PoissonSystem;
    use crate::sparse::{Coo, Csr};

    /// The paper's example nonlinearity: F(u) = A u + u^2 - f.
    pub struct QuadraticPoisson {
        pub sys: PoissonSystem,
        pub f: Vec<f64>,
    }

    impl Residual for QuadraticPoisson {
        fn dim(&self) -> usize {
            self.f.len()
        }

        fn eval(&self, u: &[f64], out: &mut [f64]) {
            self.sys.matrix.spmv(u, out);
            for i in 0..u.len() {
                out[i] += u[i] * u[i] - self.f[i];
            }
        }

        fn jacobian(&self, u: &[f64]) -> Csr {
            // A + 2 diag(u)
            let a = &self.sys.matrix;
            let n = a.nrows;
            let mut coo = Coo::with_capacity(n, n, a.nnz() + n);
            for r in 0..n {
                let (cols, vals) = a.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    coo.push(r, *c, *v);
                }
                coo.push(r, r, 2.0 * u[r]);
            }
            coo.to_csr()
        }
    }
}
