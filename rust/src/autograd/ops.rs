//! Forward op constructors on [`Tape`] and the shared backward rules.

use std::rc::Rc;
use std::sync::Arc;

use super::{CustomOp, Op, Tape, Value, Var};
use crate::util::dot as vdot;

impl Tape {
    fn vec2(&self, a: Var, b: Var, f: impl Fn(&[f64], &[f64]) -> Vec<f64>, op: Op) -> Var {
        let (va, vb) = (self.vec_of(a), self.vec_of(b));
        assert_eq!(va.len(), vb.len(), "vector length mismatch");
        let out = f(&va, &vb);
        self.push(op, vec![a, b], Value::V(out))
    }

    /// Elementwise a + b.
    pub fn add(&self, a: Var, b: Var) -> Var {
        self.vec2(a, b, |x, y| x.iter().zip(y).map(|(p, q)| p + q).collect(), Op::AddV)
    }

    /// Elementwise a - b.
    pub fn sub(&self, a: Var, b: Var) -> Var {
        self.vec2(a, b, |x, y| x.iter().zip(y).map(|(p, q)| p - q).collect(), Op::SubV)
    }

    /// Elementwise a * b.
    pub fn mul(&self, a: Var, b: Var) -> Var {
        self.vec2(a, b, |x, y| x.iter().zip(y).map(|(p, q)| p * q).collect(), Op::MulVV)
    }

    /// Elementwise a / b.
    pub fn div(&self, a: Var, b: Var) -> Var {
        self.vec2(a, b, |x, y| x.iter().zip(y).map(|(p, q)| p / q).collect(), Op::DivVV)
    }

    /// scalar-var s * vector-var v.
    pub fn mul_sv(&self, s: Var, v: Var) -> Var {
        let sv = self.scalar_of(s);
        let vv = self.vec_of(v);
        let out = vv.iter().map(|x| sv * x).collect();
        self.push(Op::MulSV, vec![s, v], Value::V(out))
    }

    /// Constant scale c * v.
    pub fn scale_const(&self, c: f64, v: Var) -> Var {
        let out = self.vec_of(v).iter().map(|x| c * x).collect();
        self.push(Op::ScaleConst(c), vec![v], Value::V(out))
    }

    /// Elementwise multiply by an untracked constant vector.
    pub fn mul_const_vec(&self, c: Arc<Vec<f64>>, v: Var) -> Var {
        let vv = self.vec_of(v);
        assert_eq!(c.len(), vv.len());
        let out = vv.iter().zip(c.iter()).map(|(x, y)| x * y).collect();
        self.push(Op::MulConstVec(c), vec![v], Value::V(out))
    }

    /// out[k] = x[idx[k]] — the gather half of the paper's scatter SpMV.
    pub fn gather(&self, x: Var, idx: Arc<Vec<usize>>) -> Var {
        let xv = self.vec_of(x);
        let out = idx.iter().map(|&i| xv[i]).collect();
        self.push(Op::Gather(idx), vec![x], Value::V(out))
    }

    /// out[i] = sum over k with idx[k] == i of v[k] (length n) — the
    /// index_add half of the scatter SpMV.
    pub fn index_add(&self, v: Var, idx: Arc<Vec<usize>>, n: usize) -> Var {
        let vv = self.vec_of(v);
        assert_eq!(vv.len(), idx.len());
        let mut out = vec![0.0; n];
        for (k, &i) in idx.iter().enumerate() {
            out[i] += vv[k];
        }
        self.push(Op::IndexAdd(idx, n), vec![v], Value::V(out))
    }

    /// Numerically stable softplus ln(1 + e^x).
    pub fn softplus(&self, v: Var) -> Var {
        let out = self
            .vec_of(v)
            .iter()
            .map(|&x| if x > 30.0 { x } else { (1.0 + x.exp()).ln() })
            .collect();
        self.push(Op::Softplus, vec![v], Value::V(out))
    }

    /// Concatenate vectors.
    pub fn concat(&self, parts: &[Var]) -> Var {
        let vals: Vec<Vec<f64>> = parts.iter().map(|&p| self.vec_of(p)).collect();
        let lens: Vec<usize> = vals.iter().map(|v| v.len()).collect();
        let mut out = Vec::with_capacity(lens.iter().sum());
        for v in &vals {
            out.extend_from_slice(v);
        }
        self.push(Op::ConcatN(lens), parts.to_vec(), Value::V(out))
    }

    /// Slice v[start..start+len].
    pub fn slice(&self, v: Var, start: usize, len: usize) -> Var {
        let vv = self.vec_of(v);
        let out = vv[start..start + len].to_vec();
        self.push(Op::Slice(start, len), vec![v], Value::V(out))
    }

    /// Inner product -> scalar.
    pub fn dot(&self, a: Var, b: Var) -> Var {
        let (va, vb) = (self.vec_of(a), self.vec_of(b));
        assert_eq!(va.len(), vb.len());
        self.push(Op::Dot, vec![a, b], Value::S(vdot(&va, &vb)))
    }

    /// Sum of entries -> scalar.
    pub fn sum(&self, v: Var) -> Var {
        let s = self.vec_of(v).iter().sum();
        self.push(Op::SumV, vec![v], Value::S(s))
    }

    pub fn add_ss(&self, a: Var, b: Var) -> Var {
        let s = self.scalar_of(a) + self.scalar_of(b);
        self.push(Op::AddSS, vec![a, b], Value::S(s))
    }

    pub fn sub_ss(&self, a: Var, b: Var) -> Var {
        let s = self.scalar_of(a) - self.scalar_of(b);
        self.push(Op::SubSS, vec![a, b], Value::S(s))
    }

    pub fn mul_ss(&self, a: Var, b: Var) -> Var {
        let s = self.scalar_of(a) * self.scalar_of(b);
        self.push(Op::MulSS, vec![a, b], Value::S(s))
    }

    pub fn div_ss(&self, a: Var, b: Var) -> Var {
        let s = self.scalar_of(a) / self.scalar_of(b);
        self.push(Op::DivSS, vec![a, b], Value::S(s))
    }

    pub fn scale_const_s(&self, c: f64, a: Var) -> Var {
        let s = c * self.scalar_of(a);
        self.push(Op::ScaleConstS(c), vec![a], Value::S(s))
    }

    /// Insert a custom O(1) node (the adjoint framework entry point).
    /// `value` must already be computed by the caller's forward pass.
    pub fn custom(&self, op: Rc<dyn CustomOp>, inputs: Vec<Var>, value: Value) -> Var {
        self.push(Op::Custom(op), inputs, value)
    }
}

/// Backward rule dispatch: returns one Option<Value> per input.
pub(crate) fn backward_op(
    op: &Op,
    out_val: &Value,
    g: &Value,
    inputs: &[&Value],
) -> Vec<Option<Value>> {
    match op {
        Op::Leaf { .. } | Op::Constant => vec![],
        Op::AddV => vec![Some(g.clone()), Some(g.clone())],
        Op::SubV => {
            let gv = g.as_vec();
            vec![
                Some(g.clone()),
                Some(Value::V(gv.iter().map(|x| -x).collect())),
            ]
        }
        Op::MulVV => {
            let gv = g.as_vec();
            let (a, b) = (inputs[0].as_vec(), inputs[1].as_vec());
            vec![
                Some(Value::V(gv.iter().zip(b).map(|(x, y)| x * y).collect())),
                Some(Value::V(gv.iter().zip(a).map(|(x, y)| x * y).collect())),
            ]
        }
        Op::DivVV => {
            let gv = g.as_vec();
            let (a, b) = (inputs[0].as_vec(), inputs[1].as_vec());
            let da: Vec<f64> = gv.iter().zip(b).map(|(x, y)| x / y).collect();
            let db: Vec<f64> = (0..gv.len())
                .map(|i| -gv[i] * a[i] / (b[i] * b[i]))
                .collect();
            vec![Some(Value::V(da)), Some(Value::V(db))]
        }
        Op::MulSV => {
            let gv = g.as_vec();
            let s = inputs[0].as_scalar();
            let v = inputs[1].as_vec();
            vec![
                Some(Value::S(vdot(gv, v))),
                Some(Value::V(gv.iter().map(|x| s * x).collect())),
            ]
        }
        Op::ScaleConst(c) => {
            let gv = g.as_vec();
            vec![Some(Value::V(gv.iter().map(|x| c * x).collect()))]
        }
        Op::MulConstVec(c) => {
            let gv = g.as_vec();
            vec![Some(Value::V(
                gv.iter().zip(c.iter()).map(|(x, y)| x * y).collect(),
            ))]
        }
        Op::Gather(idx) => {
            let gv = g.as_vec();
            let n = inputs[0].as_vec().len();
            let mut dx = vec![0.0; n];
            for (k, &i) in idx.iter().enumerate() {
                dx[i] += gv[k];
            }
            vec![Some(Value::V(dx))]
        }
        Op::IndexAdd(idx, n) => {
            let gv = g.as_vec();
            debug_assert_eq!(gv.len(), *n);
            vec![Some(Value::V(idx.iter().map(|&i| gv[i]).collect()))]
        }
        Op::Softplus => {
            let gv = g.as_vec();
            let x = inputs[0].as_vec();
            let dx: Vec<f64> = gv
                .iter()
                .zip(x)
                .map(|(gi, xi)| gi / (1.0 + (-xi).exp()))
                .collect();
            vec![Some(Value::V(dx))]
        }
        Op::ConcatN(lens) => {
            let gv = g.as_vec();
            let mut out = Vec::with_capacity(lens.len());
            let mut off = 0;
            for &l in lens {
                out.push(Some(Value::V(gv[off..off + l].to_vec())));
                off += l;
            }
            out
        }
        Op::Slice(start, len) => {
            let gv = g.as_vec();
            let n = inputs[0].as_vec().len();
            let mut dx = vec![0.0; n];
            dx[*start..start + len].copy_from_slice(gv);
            vec![Some(Value::V(dx))]
        }
        Op::Dot => {
            let gs = g.as_scalar();
            let (a, b) = (inputs[0].as_vec(), inputs[1].as_vec());
            vec![
                Some(Value::V(b.iter().map(|x| gs * x).collect())),
                Some(Value::V(a.iter().map(|x| gs * x).collect())),
            ]
        }
        Op::SumV => {
            let gs = g.as_scalar();
            let n = inputs[0].as_vec().len();
            vec![Some(Value::V(vec![gs; n]))]
        }
        Op::AddSS => vec![Some(g.clone()), Some(g.clone())],
        Op::SubSS => vec![Some(g.clone()), Some(Value::S(-g.as_scalar()))],
        Op::MulSS => {
            let gs = g.as_scalar();
            vec![
                Some(Value::S(gs * inputs[1].as_scalar())),
                Some(Value::S(gs * inputs[0].as_scalar())),
            ]
        }
        Op::DivSS => {
            let gs = g.as_scalar();
            let (a, b) = (inputs[0].as_scalar(), inputs[1].as_scalar());
            vec![
                Some(Value::S(gs / b)),
                Some(Value::S(-gs * a / (b * b))),
            ]
        }
        Op::ScaleConstS(c) => vec![Some(Value::S(c * g.as_scalar()))],
        Op::Custom(cop) => cop.backward(out_val, g, inputs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Prng;

    /// Central finite-difference gradcheck for a tape program.
    fn gradcheck<F>(build: F, x0: Vec<f64>, tol: f64)
    where
        F: Fn(&Tape, Var) -> Var,
    {
        let t = Tape::new();
        let x = t.leaf_vec(x0.clone());
        let loss = build(&t, x);
        let g = t.backward(loss);
        let analytic = g.vec(x).clone();

        let eps = 1e-6;
        for i in 0..x0.len() {
            let mut xp = x0.clone();
            xp[i] += eps;
            let tp = Tape::new();
            let vp = tp.leaf_vec(xp);
            let lp = tp.scalar_of(build(&tp, vp));
            let mut xm = x0.clone();
            xm[i] -= eps;
            let tm = Tape::new();
            let vm = tm.leaf_vec(xm);
            let lm = tm.scalar_of(build(&tm, vm));
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (analytic[i] - fd).abs() < tol * (1.0 + fd.abs()),
                "component {i}: analytic {} vs fd {fd}",
                analytic[i]
            );
        }
    }

    #[test]
    fn gradcheck_elementwise_chain() {
        let mut rng = Prng::new(0);
        let x0 = rng.normal_vec(8);
        gradcheck(
            |t, x| {
                let y = t.mul(x, x); // x^2
                let z = t.softplus(y);
                let w = t.scale_const(0.5, z);
                t.sum(w)
            },
            x0,
            1e-6,
        );
    }

    #[test]
    fn gradcheck_gather_index_add() {
        let mut rng = Prng::new(1);
        let x0 = rng.normal_vec(6);
        let idx = Arc::new(vec![0usize, 2, 2, 5, 1, 0, 3]);
        gradcheck(
            move |t, x| {
                let gathered = t.gather(x, idx.clone());
                let sq = t.mul(gathered, gathered);
                let summed = t.index_add(sq, idx.clone(), 6);
                t.dot(summed, summed)
            },
            x0,
            1e-5,
        );
    }

    #[test]
    fn gradcheck_div_and_scalar_ops() {
        let mut rng = Prng::new(2);
        let x0: Vec<f64> = rng.normal_vec(5).iter().map(|v| v + 3.0).collect();
        gradcheck(
            |t, x| {
                let c = t.constant_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
                let q = t.div(c, x);
                let d1 = t.dot(q, q);
                let d2 = t.sum(x);
                let r = t.div_ss(d1, d2);
                t.scale_const_s(2.0, r)
            },
            x0,
            1e-5,
        );
    }

    #[test]
    fn gradcheck_concat_slice() {
        let mut rng = Prng::new(3);
        let x0 = rng.normal_vec(6);
        gradcheck(
            |t, x| {
                let a = t.slice(x, 0, 3);
                let b = t.slice(x, 3, 3);
                let c = t.concat(&[b, a]);
                let d = t.mul(c, c);
                t.sum(d)
            },
            x0,
            1e-6,
        );
    }

    #[test]
    fn gradcheck_mul_sv() {
        let mut rng = Prng::new(4);
        let x0 = rng.normal_vec(4);
        gradcheck(
            |t, x| {
                let s = t.sum(x);
                let y = t.mul_sv(s, x);
                t.sum(y)
            },
            x0,
            1e-6,
        );
    }
}
