//! Reverse-mode autograd engine — the PyTorch-autograd stand-in.
//!
//! A [`Tape`] records every differentiable operation as a node holding
//! its forward value; [`Tape::backward`] walks the nodes in reverse and
//! accumulates gradients.  Two properties matter for the paper:
//!
//! 1. **The naive path is faithfully expensive.**  SpMV is recorded as
//!    the paper's scatter decomposition (gather -> elementwise multiply
//!    -> index_add), so every CG iteration pins two nnz-sized
//!    intermediates plus a handful of n-vectors — the O(k·n) tape growth
//!    of Fig. 2 is *measured* via [`Tape::forward_bytes`].
//! 2. **Custom O(1) nodes.**  [`CustomOp`] lets the adjoint framework
//!    ([`crate::adjoint`]) insert a solve as ONE node that stashes only
//!    (A, x*), independent of solver iterations — paper Table 2.
//!
//! The engine is deliberately minimal: f64 vectors and scalars, the op
//! set needed for Krylov loops, losses, and differentiable stencil
//! assembly.

pub mod naive_cg;
pub mod ops;

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

/// A value on the tape: vector or scalar.
#[derive(Clone, Debug)]
pub enum Value {
    V(Vec<f64>),
    S(f64),
}

impl Value {
    pub fn as_vec(&self) -> &Vec<f64> {
        match self {
            Value::V(v) => v,
            Value::S(_) => panic!("expected vector value"), // rsla-lint: allow(L1, typed accessor; wrong-kind access is a tape programming error)
        }
    }

    pub fn as_scalar(&self) -> f64 {
        match self {
            Value::S(s) => *s,
            Value::V(_) => panic!("expected scalar value"), // rsla-lint: allow(L1, typed accessor; wrong-kind access is a tape programming error)
        }
    }

    pub fn bytes(&self) -> usize {
        match self {
            Value::V(v) => v.len() * 8,
            Value::S(_) => 8,
        }
    }
}

/// Handle to a tape node.
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub struct Var(pub(crate) usize);

/// A custom differentiable operation (the adjoint framework's hook).
pub trait CustomOp {
    fn name(&self) -> &'static str;

    /// Given the node's output value, the incoming gradient, and the
    /// input values, return one gradient per input (None = not needed).
    fn backward(
        &self,
        out_val: &Value,
        out_grad: &Value,
        inputs: &[&Value],
    ) -> Vec<Option<Value>>;

    /// Extra bytes stashed by the node beyond its output value (for
    /// memory accounting; e.g. eigenvectors kept for Hellmann–Feynman).
    fn saved_bytes(&self) -> usize {
        0
    }
}

pub(crate) enum Op {
    Leaf { requires_grad: bool },
    /// Constant (no gradient ever flows).
    Constant,
    AddV,
    SubV,
    /// Elementwise multiply.
    MulVV,
    /// Elementwise divide a / b.
    DivVV,
    /// scalar-var * vec-var.
    MulSV,
    /// Multiply by an untracked constant scalar.
    ScaleConst(f64),
    /// Elementwise multiply by an untracked constant vector.
    MulConstVec(Arc<Vec<f64>>),
    /// out[k] = x[idx[k]].
    Gather(Arc<Vec<usize>>),
    /// out[i] = sum_{k: idx[k] == i} v[k]; output length stored.
    IndexAdd(Arc<Vec<usize>>, usize),
    /// Softplus ln(1 + e^x) (numerically stable).
    Softplus,
    /// Concatenate input vectors.
    ConcatN(Vec<usize>),
    /// Vector slice [start, start+len).
    Slice(usize, usize),
    Dot,
    SumV,
    AddSS,
    SubSS,
    MulSS,
    DivSS,
    ScaleConstS(f64),
    Custom(Rc<dyn CustomOp>),
}

pub(crate) struct Node {
    pub op: Op,
    pub inputs: Vec<Var>,
    pub value: Value,
}

/// The gradient tape.  Single-threaded (`RefCell`), like a PyTorch graph.
pub struct Tape {
    nodes: RefCell<Vec<Node>>,
}

impl Default for Tape {
    fn default() -> Self {
        Self::new()
    }
}

impl Tape {
    pub fn new() -> Self {
        Tape {
            nodes: RefCell::new(Vec::new()),
        }
    }

    pub(crate) fn push(&self, op: Op, inputs: Vec<Var>, value: Value) -> Var {
        let mut nodes = self.nodes.borrow_mut();
        nodes.push(Node { op, inputs, value });
        Var(nodes.len() - 1)
    }

    /// Differentiable input.
    pub fn leaf_vec(&self, v: Vec<f64>) -> Var {
        self.push(Op::Leaf { requires_grad: true }, vec![], Value::V(v))
    }

    pub fn leaf_scalar(&self, s: f64) -> Var {
        self.push(Op::Leaf { requires_grad: true }, vec![], Value::S(s))
    }

    /// Non-differentiable input.
    pub fn constant_vec(&self, v: Vec<f64>) -> Var {
        self.push(Op::Constant, vec![], Value::V(v))
    }

    pub fn value(&self, v: Var) -> Value {
        self.nodes.borrow()[v.0].value.clone()
    }

    pub fn vec_of(&self, v: Var) -> Vec<f64> {
        self.nodes.borrow()[v.0].value.as_vec().clone()
    }

    pub fn scalar_of(&self, v: Var) -> f64 {
        self.nodes.borrow()[v.0].value.as_scalar()
    }

    pub fn node_count(&self) -> usize {
        self.nodes.borrow().len()
    }

    /// Bytes pinned by forward values (the paper's "autograd-tracked
    /// intermediates"; Fig. 2 left panel measures exactly this).
    pub fn forward_bytes(&self) -> usize {
        self.nodes
            .borrow()
            .iter()
            .map(|n| {
                n.value.bytes()
                    + match &n.op {
                        Op::Custom(c) => c.saved_bytes(),
                        _ => 0,
                    }
            })
            .sum()
    }

    /// Run reverse-mode accumulation from scalar `loss`; returns a
    /// gradient table indexed by Var.
    pub fn backward(&self, loss: Var) -> Grads {
        let nodes = self.nodes.borrow();
        assert!(
            matches!(nodes[loss.0].value, Value::S(_)),
            "backward needs a scalar loss"
        );
        let mut grads: Vec<Option<Value>> = vec![None; nodes.len()];
        grads[loss.0] = Some(Value::S(1.0));

        for i in (0..=loss.0).rev() {
            let g = match grads[i].take() {
                Some(g) => g,
                None => continue,
            };
            let node = &nodes[i];
            let input_vals: Vec<&Value> =
                node.inputs.iter().map(|v| &nodes[v.0].value).collect();
            let input_grads = ops::backward_op(&node.op, &node.value, &g, &input_vals);
            debug_assert_eq!(input_grads.len(), node.inputs.len());
            for (var, ig) in node.inputs.iter().zip(input_grads) {
                if let Some(ig) = ig {
                    accumulate(&mut grads[var.0], ig);
                }
            }
            // keep leaf gradients; interior grads were taken above
            if matches!(node.op, Op::Leaf { requires_grad: true }) {
                grads[i] = Some(g);
            }
        }
        Grads { grads }
    }
}

fn accumulate(slot: &mut Option<Value>, add: Value) {
    match slot {
        None => *slot = Some(add),
        Some(Value::S(s)) => *s += add.as_scalar(),
        Some(Value::V(v)) => {
            let av = add.as_vec();
            for (x, y) in v.iter_mut().zip(av) {
                *x += y;
            }
        }
    }
}

/// Gradient table returned by [`Tape::backward`].
pub struct Grads {
    grads: Vec<Option<Value>>,
}

impl Grads {
    pub fn get(&self, v: Var) -> Option<&Value> {
        self.grads.get(v.0).and_then(|g| g.as_ref())
    }

    pub fn vec(&self, v: Var) -> &Vec<f64> {
        self.get(v).expect("no gradient recorded").as_vec() // rsla-lint: allow(L1, typed accessor; caller asserts a gradient was recorded)
    }

    pub fn scalar(&self, v: Var) -> f64 {
        self.get(v).expect("no gradient recorded").as_scalar() // rsla-lint: allow(L1, typed accessor; caller asserts a gradient was recorded)
    }

    pub fn bytes(&self) -> usize {
        self.grads
            .iter()
            .map(|g| g.as_ref().map(|v| v.bytes()).unwrap_or(0))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_chain() {
        // L = (a * b + c)^2 via MulSS/AddSS; dL/da = 2(ab+c) b
        let t = Tape::new();
        let a = t.leaf_scalar(3.0);
        let b = t.leaf_scalar(4.0);
        let c = t.leaf_scalar(1.0);
        let ab = t.mul_ss(a, b);
        let abc = t.add_ss(ab, c);
        let loss = t.mul_ss(abc, abc);
        assert_eq!(t.scalar_of(loss), 169.0);
        let g = t.backward(loss);
        assert!((g.scalar(a) - 2.0 * 13.0 * 4.0).abs() < 1e-12);
        assert!((g.scalar(b) - 2.0 * 13.0 * 3.0).abs() < 1e-12);
        assert!((g.scalar(c) - 2.0 * 13.0).abs() < 1e-12);
    }

    #[test]
    fn vector_dot_gradient() {
        let t = Tape::new();
        let x = t.leaf_vec(vec![1.0, 2.0, 3.0]);
        let y = t.leaf_vec(vec![4.0, 5.0, 6.0]);
        let d = t.dot(x, y);
        assert_eq!(t.scalar_of(d), 32.0);
        let g = t.backward(d);
        assert_eq!(g.vec(x), &vec![4.0, 5.0, 6.0]);
        assert_eq!(g.vec(y), &vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn gradient_accumulates_across_uses() {
        // L = <x, x> -> dL/dx = 2x (x used twice)
        let t = Tape::new();
        let x = t.leaf_vec(vec![1.0, -2.0]);
        let d = t.dot(x, x);
        let g = t.backward(d);
        assert_eq!(g.vec(x), &vec![2.0, -4.0]);
    }

    #[test]
    fn constants_get_no_grad() {
        let t = Tape::new();
        let x = t.leaf_vec(vec![1.0, 2.0]);
        let c = t.constant_vec(vec![3.0, 4.0]);
        let d = t.dot(x, c);
        let g = t.backward(d);
        assert_eq!(g.vec(x), &vec![3.0, 4.0]);
        assert!(g.get(c).is_none());
    }

    #[test]
    fn forward_bytes_counts_values() {
        let t = Tape::new();
        let x = t.leaf_vec(vec![0.0; 100]); // 800 B
        let y = t.scale_const(2.0, x); // 800 B
        let _ = t.dot(y, y); // 8 B
        assert_eq!(t.forward_bytes(), 1608);
        assert_eq!(t.node_count(), 3);
    }
}
