//! "Naive" CG: every iteration recorded on the autograd tape — the
//! baseline of paper §4.2 / Fig. 2 / Table 7.
//!
//! SpMV is decomposed exactly as the paper's hand-coded scatter SpMV
//! (`val * x[col]` followed by `index_add`): a gather node and a
//! multiply node each pin an nnz-sized tensor per iteration, plus a
//! handful of n-vectors from the Krylov recurrence — reproducing the
//! ~(2 nnz + c n) * 8 bytes/iteration growth measured in the paper.

use std::sync::Arc;

use super::{Tape, Var};
use crate::sparse::Pattern;

/// Sparse structure prepared for tape SpMV: gather/scatter index maps.
pub struct TapeSpmv {
    pub n: usize,
    cols: Arc<Vec<usize>>,
    rows: Arc<Vec<usize>>,
}

impl TapeSpmv {
    pub fn new(pattern: &Pattern) -> Self {
        let mut rows = vec![0usize; pattern.nnz()];
        for r in 0..pattern.nrows {
            for k in pattern.indptr[r]..pattern.indptr[r + 1] {
                rows[k] = r;
            }
        }
        TapeSpmv {
            n: pattern.nrows,
            cols: Arc::new(pattern.indices.as_ref().clone()),
            rows: Arc::new(rows),
        }
    }

    /// y = A x recorded as gather -> mul -> index_add (3 tape nodes, two
    /// of them nnz-sized).
    pub fn apply(&self, tape: &Tape, vals: Var, x: Var) -> Var {
        let gathered = tape.gather(x, self.cols.clone());
        let prod = tape.mul(vals, gathered);
        tape.index_add(prod, self.rows.clone(), self.n)
    }
}

/// Unpreconditioned CG forced to run exactly `k` iterations, all ops on
/// the tape.  Returns the solution Var; gradients w.r.t. `vals` and `b`
/// flow back through every iteration (O(k) nodes, O(k (n + nnz)) bytes).
pub fn naive_cg(tape: &Tape, spmv: &TapeSpmv, vals: Var, b: Var, k: usize) -> Var {
    naive_cg_tol(tape, spmv, vals, b, k, 0.0)
}

/// Like [`naive_cg`] but with an absolute-residual stop (the paper's
/// convergence-agreement protocol, §4.2/App. D: atol = 1e-12): once
/// ||r|| <= tol the loop stops adding tape nodes, avoiding the 0/0
/// degeneracy of iterating far past floating-point convergence.
pub fn naive_cg_tol(
    tape: &Tape,
    spmv: &TapeSpmv,
    vals: Var,
    b: Var,
    k: usize,
    tol: f64,
) -> Var {
    let n = spmv.n;
    let tol2 = tol * tol;
    // x = 0, r = b, p = b
    let mut x = tape.constant_vec(vec![0.0; n]);
    let mut r = b;
    let mut p = b;
    let mut rz = tape.dot(r, r);
    for _ in 0..k {
        if tape.scalar_of(rz) <= tol2 {
            break;
        }
        let ap = spmv.apply(tape, vals, p);
        let pap = tape.dot(p, ap);
        let alpha = tape.div_ss(rz, pap);
        let alpha_p = tape.mul_sv(alpha, p);
        x = tape.add(x, alpha_p);
        let alpha_ap = tape.mul_sv(alpha, ap);
        r = tape.sub(r, alpha_ap);
        let rz_new = tape.dot(r, r);
        let beta = tape.div_ss(rz_new, rz);
        let beta_p = tape.mul_sv(beta, p);
        p = tape.add(r, beta_p);
        rz = rz_new;
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adjoint::{native_solver, solve_linear};
    use crate::sparse::poisson::poisson2d;
    use crate::util::{self, Prng};

    #[test]
    fn tape_spmv_matches_csr() {
        let g = 8;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let spmv = TapeSpmv::new(&pattern);
        let mut rng = Prng::new(0);
        let xv = rng.normal_vec(g * g);
        let tape = Tape::new();
        let vals = tape.constant_vec(sys.matrix.vals.clone());
        let x = tape.constant_vec(xv.clone());
        let y = spmv.apply(&tape, vals, x);
        assert!(util::max_abs_diff(&tape.vec_of(y), &sys.matrix.matvec(&xv)) < 1e-12);
    }

    #[test]
    fn converged_naive_matches_direct() {
        let g = 8;
        let n = g * g;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let spmv = TapeSpmv::new(&pattern);
        let mut rng = Prng::new(1);
        let bv = rng.normal_vec(n);
        let tape = Tape::new();
        let vals = tape.constant_vec(sys.matrix.vals.clone());
        let b = tape.constant_vec(bv.clone());
        let x = naive_cg(&tape, &spmv, vals, b, n);
        let xd = crate::direct::direct_solve(&sys.matrix, &bv).unwrap();
        assert!(util::max_abs_diff(&tape.vec_of(x), &xd) < 1e-8);
    }

    /// The paper's §4.2 small-problem correctness check: run naive and
    /// adjoint to convergence; loss and gradients must agree.
    #[test]
    fn naive_and_adjoint_gradients_agree_at_convergence() {
        let g = 8; // small version of the paper's n_grid = 64 check
        let n = g * g;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let spmv = TapeSpmv::new(&pattern);
        let mut rng = Prng::new(2);
        let bv = rng.normal_vec(n);

        // naive path
        // k = n: CG terminates exactly at n iterations in exact
        // arithmetic; running far past that point degenerates the
        // recurrence (beta -> 0/0) and poisons the naive backward.
        let t1 = Tape::new();
        let vals1 = t1.leaf_vec(sys.matrix.vals.clone());
        let b1 = t1.leaf_vec(bv.clone());
        let x1 = naive_cg(&t1, &spmv, vals1, b1, n);
        let loss1 = t1.dot(x1, x1);
        let g1 = t1.backward(loss1);

        // adjoint path
        let t2 = Tape::new();
        let vals2 = t2.leaf_vec(sys.matrix.vals.clone());
        let b2 = t2.leaf_vec(bv.clone());
        let solver = native_solver();
        let x2 = solve_linear(&t2, &pattern, vals2, b2, &solver).unwrap();
        let loss2 = t2.dot(x2, x2);
        let g2 = t2.backward(loss2);

        // losses agree to machine precision
        let (l1, l2) = (t1.scalar_of(loss1), t2.scalar_of(loss2));
        assert!(
            ((l1 - l2) / l2).abs() < 1e-12,
            "loss mismatch: {l1} vs {l2}"
        );
        // db agree tightly, dA a bit looser (paper: 1e-14 and 1e-4 bands)
        assert!(util::rel_l2(g1.vec(b1), g2.vec(b2)) < 1e-9);
        assert!(util::rel_l2(g1.vec(vals1), g2.vec(vals2)) < 1e-5);
    }

    #[test]
    fn tape_grows_linearly_in_k() {
        let g = 8;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let spmv = TapeSpmv::new(&pattern);
        let measure = |k: usize| {
            let tape = Tape::new();
            let vals = tape.constant_vec(sys.matrix.vals.clone());
            let b = tape.constant_vec(vec![1.0; g * g]);
            let _ = naive_cg(&tape, &spmv, vals, b, k);
            (tape.node_count(), tape.forward_bytes())
        };
        let (n10, b10) = measure(10);
        let (n20, b20) = measure(20);
        let (n40, b40) = measure(40);
        // node count and bytes must grow linearly: doubling k doubles the
        // per-iteration share
        assert_eq!(n40 - n20, 2 * (n20 - n10));
        assert_eq!(b40 - b20, 2 * (b20 - b10));
    }
}
