//! Library-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`: proc-macro
//! crates are unavailable in this offline build environment).

use std::fmt;

/// Errors surfaced by rsla solvers, backends, and the runtime.
#[derive(Debug)]
pub enum Error {
    /// Solver exceeded its iteration budget without reaching tolerance.
    NotConverged {
        iters: usize,
        residual: f64,
        tol: f64,
    },

    /// Factorization breakdown (zero/negative pivot, singular matrix).
    Breakdown { at: usize, reason: String },

    /// Problem shape/property mismatch (non-square, dimension mismatch...).
    InvalidProblem(String),

    /// A backend refused the problem (device mismatch, memory budget...).
    /// The dispatcher treats this as "try the next backend".
    BackendUnavailable { backend: String, reason: String },

    /// Simulated device-memory exhaustion: the memory model predicts the
    /// solve would not fit the configured accelerator budget.  This is the
    /// analogue of the paper's CUDA OOM rows in Tables 3-4.
    OutOfMemory {
        needed_bytes: u64,
        budget_bytes: u64,
    },

    /// PJRT / XLA runtime failure.
    Xla(String),

    /// Missing or malformed AOT artifact.
    Artifact(String, String),

    /// Autograd misuse (double backward, wrong tape...).
    Autograd(String),

    /// Distributed layer failure (rank panicked, channel closed...).
    Distributed(String),

    /// A worker process in a process-separated rank team died (or went
    /// unresponsive) before reporting its result.  The whole team is
    /// reaped when this is raised — a dead rank must surface as a typed
    /// error, never a hang.
    RankDead { rank: usize, detail: String },

    /// Engine job missed its deadline while queued (it never executed).
    Timeout {
        waited_ms: u64,
        deadline_ms: u64,
    },

    /// Engine admission control rejected the job: the pending queue is
    /// at capacity (backpressure — resubmit later or shed load).
    QueueFull { depth: usize, capacity: usize },

    /// An engine worker panicked while executing the job.  The worker
    /// pool survives; only this job is lost.
    WorkerPanic(String),

    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::NotConverged {
                iters,
                residual,
                tol,
            } => write!(
                f,
                "solver did not converge: {iters} iterations, residual {residual:.3e} > tol {tol:.3e}"
            ),
            Error::Breakdown { at, reason } => {
                write!(f, "factorization breakdown at pivot {at}: {reason}")
            }
            Error::InvalidProblem(msg) => write!(f, "invalid problem: {msg}"),
            Error::BackendUnavailable { backend, reason } => {
                write!(f, "backend '{backend}' unavailable: {reason}")
            }
            Error::OutOfMemory {
                needed_bytes,
                budget_bytes,
            } => write!(
                f,
                "out of device memory: needs {needed_bytes} B > budget {budget_bytes} B"
            ),
            Error::Xla(msg) => write!(f, "xla runtime: {msg}"),
            Error::Artifact(name, msg) => write!(f, "artifact '{name}' not available: {msg}"),
            Error::Autograd(msg) => write!(f, "autograd: {msg}"),
            Error::Distributed(msg) => write!(f, "distributed: {msg}"),
            Error::RankDead { rank, detail } => {
                write!(f, "rank {rank} died before reporting: {detail}")
            }
            Error::Timeout {
                waited_ms,
                deadline_ms,
            } => write!(
                f,
                "job deadline exceeded: waited {waited_ms} ms > deadline {deadline_ms} ms"
            ),
            Error::QueueFull { depth, capacity } => {
                write!(f, "engine queue full: {depth} pending >= capacity {capacity}")
            }
            Error::WorkerPanic(msg) => write!(f, "engine worker panicked: {msg}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_matches_legacy_format() {
        let e = Error::NotConverged {
            iters: 7,
            residual: 1.5e-3,
            tol: 1e-10,
        };
        assert_eq!(
            e.to_string(),
            "solver did not converge: 7 iterations, residual 1.500e-3 > tol 1.000e-10"
        );
        let e = Error::OutOfMemory {
            needed_bytes: 100,
            budget_bytes: 10,
        };
        assert!(e.to_string().contains("needs 100 B > budget 10 B"));
        let e = Error::BackendUnavailable {
            backend: "petsc".into(),
            reason: "not registered".into(),
        };
        assert_eq!(e.to_string(), "backend 'petsc' unavailable: not registered");
        let e = Error::RankDead {
            rank: 2,
            detail: "exit status 101".into(),
        };
        assert_eq!(e.to_string(), "rank 2 died before reporting: exit status 101");
    }
}
