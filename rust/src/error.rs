//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by rsla solvers, backends, and the runtime.
#[derive(Error, Debug)]
pub enum Error {
    /// Solver exceeded its iteration budget without reaching tolerance.
    #[error("solver did not converge: {iters} iterations, residual {residual:.3e} > tol {tol:.3e}")]
    NotConverged {
        iters: usize,
        residual: f64,
        tol: f64,
    },

    /// Factorization breakdown (zero/negative pivot, singular matrix).
    #[error("factorization breakdown at pivot {at}: {reason}")]
    Breakdown { at: usize, reason: String },

    /// Problem shape/property mismatch (non-square, dimension mismatch...).
    #[error("invalid problem: {0}")]
    InvalidProblem(String),

    /// A backend refused the problem (device mismatch, memory budget...).
    /// The dispatcher treats this as "try the next backend".
    #[error("backend '{backend}' unavailable: {reason}")]
    BackendUnavailable { backend: String, reason: String },

    /// Simulated device-memory exhaustion: the memory model predicts the
    /// solve would not fit the configured accelerator budget.  This is the
    /// analogue of the paper's CUDA OOM rows in Tables 3-4.
    #[error("out of device memory: needs {needed_bytes} B > budget {budget_bytes} B")]
    OutOfMemory {
        needed_bytes: u64,
        budget_bytes: u64,
    },

    /// PJRT / XLA runtime failure.
    #[error("xla runtime: {0}")]
    Xla(String),

    /// Missing or malformed AOT artifact.
    #[error("artifact '{0}' not available: {1}")]
    Artifact(String, String),

    /// Autograd misuse (double backward, wrong tape...).
    #[error("autograd: {0}")]
    Autograd(String),

    /// Distributed layer failure (rank panicked, channel closed...).
    #[error("distributed: {0}")]
    Distributed(String),

    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
