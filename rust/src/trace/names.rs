//! Canonical span and event names for the tracing layer.
//!
//! Like `metrics/names.rs`, this is the single declaration point:
//! rsla-lint L4 scans this file (and `metrics/names.rs`) for the
//! registered vocabulary and flags any string literal passed to
//! `trace::span(` / `trace::event(` / `trace::event_job(` that is not
//! declared here.  Names follow the metric grammar
//! (`namespace.phase[.sub]`, lowercase + dots + underscores) so the
//! same hygiene test applies.

// --- job lifecycle (engine) ------------------------------------------

/// Instant: a job entered the intake queue.
pub const JOB_SUBMIT: &str = "job.submit";
/// Span: time between submission and a worker picking the job up.
pub const JOB_QUEUED: &str = "job.queued";
/// Instant: the scheduler routed the job to a worker (arg = worker).
pub const JOB_SCHEDULED: &str = "job.scheduled";
/// Instant: the job was fused into a multi-RHS batch (arg = batch size).
pub const JOB_FUSED: &str = "job.fused";
/// Span: worker-side execution of one job (or one fused batch member).
pub const JOB_EXEC: &str = "job.exec";
/// Instant: the result was handed to the reply callback.
pub const JOB_REPLY: &str = "job.reply";

// --- factor cache -----------------------------------------------------

/// Instant: numeric-tier cache hit (factorization fully reused).
pub const FACTOR_HIT_NUMERIC: &str = "factor.hit.numeric";
/// Instant: symbolic-tier hit (analysis reused, numeric refactor ran).
pub const FACTOR_HIT_SYMBOLIC: &str = "factor.hit.symbolic";
/// Instant: cold miss (full symbolic + numeric factorization).
pub const FACTOR_MISS: &str = "factor.miss";
/// Instant: the job's pattern was served by its affine shard (arg = shard).
pub const FACTOR_SHARD_LOCAL_HIT: &str = "factor.shard_local_hit";
/// Instant: cross-shard placement — the pattern's home shard differed
/// from the executing worker's (arg = shard actually used).
pub const FACTOR_CROSS_SHARD_MISS: &str = "factor.cross_shard_miss";

// --- direct stack -----------------------------------------------------

/// Span: ordering + symbolic analysis (elimination structure).
pub const DIRECT_SYMBOLIC: &str = "direct.symbolic";
/// Span: numeric factorization (cold or warm refactor).
pub const DIRECT_NUMERIC: &str = "direct.numeric";
/// Span: forward/backward triangular sweeps of one solve.
pub const DIRECT_TRISOLVE: &str = "direct.trisolve";
/// Span: blocked (supernodal/panel) numeric phase, when engaged
/// (arg = panel count).  Nested inside [`DIRECT_NUMERIC`].
pub const DIRECT_SUPERNODAL_NUMERIC: &str = "direct.supernodal.numeric";

// --- krylov kernels ---------------------------------------------------

/// Span: one preconditioned CG solve.
pub const KRYLOV_CG: &str = "krylov.cg";
/// Span: one pipelined (single-reduction) CG solve.
pub const KRYLOV_CG_PIPELINED: &str = "krylov.cg_pipelined";
/// Span: one BiCGStab solve.
pub const KRYLOV_BICGSTAB: &str = "krylov.bicgstab";
/// Span: one restarted GMRES solve.
pub const KRYLOV_GMRES: &str = "krylov.gmres";
/// Span: one MINRES solve.
pub const KRYLOV_MINRES: &str = "krylov.minres";
/// Span: one s-step communication-avoiding CG solve.
pub const KRYLOV_CA_CG: &str = "krylov.ca_cg";
/// Instant: the CA-CG drift guard replaced the recurrence residual with
/// the true residual (arg = outer step).
pub const KRYLOV_CA_REPLACE: &str = "krylov.ca_cg.replace";
/// Instant: CA-CG abandoned the s-step recurrence and fell back to
/// standard CG (arg = iterations already spent).
pub const KRYLOV_CA_FALLBACK: &str = "krylov.ca_cg.fallback";
/// Instant: a Krylov recurrence broke down (arg = iteration).
pub const KRYLOV_BREAKDOWN: &str = "krylov.breakdown";
/// Instant: GMRES restarted its basis (arg = restart ordinal).
pub const KRYLOV_RESTART: &str = "krylov.restart";

// --- distributed / backend -------------------------------------------

/// Convergence record: one per-rank distributed solve, carrying the
/// reduction-round and halo-byte deltas of that solve.
pub const DIST_SOLVE: &str = "dist.solve";
/// Span: lifetime of one process-separated rank team, spawn through
/// reap (arg = team size).
pub const COMM_TEAM: &str = "comm.team";
/// Span: one backend dispatch through `NativeIter::solve`.
pub const BACKEND_SOLVE: &str = "backend.solve";

/// Every declared trace name, for hygiene tests and exporters.
pub const ALL: &[&str] = &[
    JOB_SUBMIT,
    JOB_QUEUED,
    JOB_SCHEDULED,
    JOB_FUSED,
    JOB_EXEC,
    JOB_REPLY,
    FACTOR_HIT_NUMERIC,
    FACTOR_HIT_SYMBOLIC,
    FACTOR_MISS,
    FACTOR_SHARD_LOCAL_HIT,
    FACTOR_CROSS_SHARD_MISS,
    DIRECT_SYMBOLIC,
    DIRECT_NUMERIC,
    DIRECT_TRISOLVE,
    DIRECT_SUPERNODAL_NUMERIC,
    KRYLOV_CG,
    KRYLOV_CG_PIPELINED,
    KRYLOV_BICGSTAB,
    KRYLOV_GMRES,
    KRYLOV_MINRES,
    KRYLOV_CA_CG,
    KRYLOV_CA_REPLACE,
    KRYLOV_CA_FALLBACK,
    KRYLOV_BREAKDOWN,
    KRYLOV_RESTART,
    DIST_SOLVE,
    COMM_TEAM,
    BACKEND_SOLVE,
];

#[cfg(test)]
mod tests {
    use super::ALL;
    use std::collections::HashSet;

    #[test]
    fn names_are_unique_and_well_formed() {
        let mut seen = HashSet::new();
        for name in ALL {
            assert!(seen.insert(*name), "duplicate trace name {name}");
            assert!(name.contains('.'), "{name} must be namespace.phase shaped");
            assert!(
                name.chars()
                    .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '.' || c == '_'),
                "{name} must be lowercase dotted"
            );
        }
    }

    #[test]
    fn trace_names_do_not_collide_with_metric_names() {
        let metrics: HashSet<&str> = crate::metrics::names::ALL.iter().copied().collect();
        for name in ALL {
            assert!(
                !metrics.contains(name),
                "{name} is declared in both trace/names.rs and metrics/names.rs"
            );
        }
    }
}
