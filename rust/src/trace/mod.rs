//! `rsla-trace` — process-wide span tracing and solve telemetry.
//!
//! Design contract (see `docs/observability.md`):
//!
//! - **Disabled cost ≈ one branch.**  Every recording entry point
//!   loads one relaxed atomic and returns; no clock read, no
//!   thread-local touch, no allocation.  The disabled path is safe
//!   inside L5 `no_alloc` warm loops.
//! - **Enabled path is lock-free on the hot side.**  Each (thread,
//!   tracer) pair owns a preallocated write-once ring ([`Ring`]): the
//!   owner thread appends with a relaxed read + release store of
//!   `len`; snapshot readers acquire `len` and read the published
//!   prefix.  Slots are never overwritten — when a ring fills, new
//!   records are counted in `dropped` instead (never silently lost).
//!   The only mutex (`bufs`, deliberately outside the L2 lock
//!   hierarchy) guards ring *registration*, touched once per thread.
//! - **Tracing records, never reorders, arithmetic.**  No instrument
//!   introduces FP operations that feed a solver; the bitwise pins in
//!   `tests/krylov_equivalence.rs` hold with tracing enabled.
//!
//! Spans carry the job context ([`JobCtx`]) of the recording thread —
//! job id, [`crate::engine::JobKind`] name, `PatternKey` structure
//! hash, worker id — so one exported trace answers "where did job 47
//! spend its time" without joining side tables.

use std::cell::{Cell, RefCell, UnsafeCell};
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::lock_recover;

pub mod export;
pub mod names;

pub use export::{validate_chrome_trace, TraceSummary};

/// Per-thread span ring capacity (spans beyond this are dropped, and
/// counted: see [`TraceSnapshot::dropped`]).
pub const SPAN_CAPACITY: usize = 1 << 14;
/// Per-thread convergence-record ring capacity.
pub const CONV_CAPACITY: usize = 1 << 11;
/// Residual-history ring length inside one [`ConvRecord`]: the LAST
/// `HISTORY_RING` residual norms of a solve (enough to see the tail
/// behaviour that explains "why 340 iterations").
pub const HISTORY_RING: usize = 32;
/// Nesting depth tracked for parent-span attribution.
const PARENT_DEPTH: usize = 16;

// ---------------------------------------------------------------------
// records
// ---------------------------------------------------------------------

/// Is this record a duration or a point event?
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Phase {
    /// A closed interval (`ph: "X"` in chrome trace terms).
    Span,
    /// An instantaneous event (`ph: "i"`).
    Event,
}

/// One recorded span or event.  `Copy` so rings never run `Drop` glue.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub name: &'static str,
    pub phase: Phase,
    /// Nanoseconds since the tracer's epoch.
    pub t_start_ns: u64,
    /// End time; equals `t_start_ns` for events.
    pub t_end_ns: u64,
    /// Unique span id (0 is reserved for "no parent").
    pub id: u64,
    /// Enclosing span's id on the same thread, 0 at top level.
    pub parent: u64,
    /// Dense per-tracer thread number (stable across the trace).
    pub thread: u32,
    /// Job context captured at record time; zeros outside a job scope.
    pub job_id: u64,
    /// `JobKind::name()` of the enclosing job, "" outside a job scope.
    pub job_kind: &'static str,
    /// `PatternKey` structure hash of the enclosing job's matrix.
    pub structure_hash: u64,
    /// Executing worker id (u32::MAX outside a worker).
    pub worker: u32,
    /// Free per-name argument (shard id, batch size, iteration, ...).
    pub arg: u64,
}

/// Per-solve convergence telemetry emitted by [`ConvergenceTrace`].
#[derive(Clone, Copy, Debug)]
pub struct ConvRecord {
    /// Kernel name from [`names`] (`krylov.cg`, `dist.solve`, ...).
    pub name: &'static str,
    /// Nanoseconds since epoch at emission.
    pub t_ns: u64,
    pub thread: u32,
    pub job_id: u64,
    pub job_kind: &'static str,
    pub structure_hash: u64,
    pub iters: u64,
    pub residual: f64,
    pub converged: bool,
    pub breakdown: bool,
    /// GMRES basis restarts observed during the solve.
    pub restarts: u32,
    /// Reduction rounds consumed (distributed solves; 0 serial).
    pub reduce_rounds: u64,
    /// Halo bytes sent (distributed solves; 0 serial).
    pub halo_bytes: u64,
    /// Total residual norms recorded (may exceed `HISTORY_RING`).
    pub hist_total: u64,
    /// The last `min(hist_total, HISTORY_RING)` residual norms, oldest
    /// first once unwrapped by the exporter.
    pub history: [f64; HISTORY_RING],
}

/// Everything a snapshot sees: published spans + convergence records
/// from every registered thread, plus the drop tally.
#[derive(Clone, Debug, Default)]
pub struct TraceSnapshot {
    pub spans: Vec<Span>,
    pub convs: Vec<ConvRecord>,
    /// Records discarded because a per-thread ring filled.
    pub dropped: u64,
}

// ---------------------------------------------------------------------
// write-once ring
// ---------------------------------------------------------------------

/// Single-producer, multi-reader append-only ring.  The OWNER thread
/// is the only writer; slots below the published `len` are immutable.
struct Ring<T: Copy> {
    slots: Box<[UnsafeCell<MaybeUninit<T>>]>,
    len: AtomicUsize,
    dropped: AtomicU64,
}

// SAFETY: only the owning thread writes, and every slot a reader can
// reach (index < len loaded with Acquire) was fully written before the
// matching Release store of `len` and is never written again.
unsafe impl<T: Copy + Send> Sync for Ring<T> {}
unsafe impl<T: Copy + Send> Send for Ring<T> {}

impl<T: Copy> Ring<T> {
    fn new(capacity: usize) -> Self {
        let mut slots = Vec::with_capacity(capacity);
        slots.resize_with(capacity, || UnsafeCell::new(MaybeUninit::uninit()));
        Ring {
            slots: slots.into_boxed_slice(),
            len: AtomicUsize::new(0),
            dropped: AtomicU64::new(0),
        }
    }

    /// Owner-thread append.  Full ring drops the NEW record (old spans
    /// stay intact — the head of a trace explains the tail).
    fn push(&self, value: T) {
        let i = self.len.load(Ordering::Relaxed);
        match self.slots.get(i) {
            Some(slot) => {
                // SAFETY: slot i is above the published len, so no
                // reader looks at it yet, and only this (owner) thread
                // writes; the Release store below publishes it.
                unsafe { (*slot.get()).write(value) };
                self.len.store(i + 1, Ordering::Release);
            }
            None => {
                self.dropped.fetch_add(1, Ordering::Relaxed);
            }
        }
    }

    fn snapshot_into(&self, out: &mut Vec<T>) {
        let n = self.len.load(Ordering::Acquire);
        for slot in self.slots.iter().take(n) {
            // SAFETY: indices below the Acquire-loaded len were
            // initialized before the matching Release store.
            out.push(unsafe { (*slot.get()).assume_init() });
        }
    }
}

/// One thread's rings, shared between the owner (writer) and
/// snapshotters through the tracer's registry.
struct ThreadBuf {
    thread: u32,
    spans: Ring<Span>,
    convs: Ring<ConvRecord>,
}

// ---------------------------------------------------------------------
// tracer
// ---------------------------------------------------------------------

/// The tracing facility.  Usually used through the process-wide
/// [`Tracer::global`] and the free functions below; instantiable for
/// tests that need an isolated trace.
pub struct Tracer {
    enabled: AtomicBool,
    epoch: Instant,
    /// Distinguishes tracers in the thread-local ring lookup.
    tracer_id: usize,
    next_span_id: AtomicU64,
    next_thread: AtomicU32,
    /// Ring REGISTRATION only (once per thread per tracer); never held
    /// while recording.  Deliberately outside the L2 lock hierarchy —
    /// it is a leaf taken from arbitrary call stacks.
    bufs: Mutex<Vec<Arc<ThreadBuf>>>,
}

static TRACER_IDS: AtomicUsize = AtomicUsize::new(1);
static GLOBAL: OnceLock<Tracer> = OnceLock::new();

thread_local! {
    /// (tracer_id, rings) pairs this thread has registered.
    static TL_BUFS: RefCell<Vec<(usize, Arc<ThreadBuf>)>> = const { RefCell::new(Vec::new()) };
    /// Open-span id stack for parent attribution (per thread).
    static TL_PARENTS: Cell<[u64; PARENT_DEPTH]> = const { Cell::new([0; PARENT_DEPTH]) };
    static TL_DEPTH: Cell<usize> = const { Cell::new(0) };
    static TL_CTX: Cell<JobCtx> = const { Cell::new(JobCtx::NONE) };
}

impl Default for Tracer {
    fn default() -> Self {
        Self::new()
    }
}

impl Tracer {
    pub fn new() -> Self {
        Tracer {
            enabled: AtomicBool::new(false),
            epoch: Instant::now(),
            tracer_id: TRACER_IDS.fetch_add(1, Ordering::Relaxed),
            next_span_id: AtomicU64::new(1),
            next_thread: AtomicU32::new(0),
            bufs: Mutex::new(Vec::new()),
        }
    }

    /// The process-wide tracer every instrument records into.
    pub fn global() -> &'static Tracer {
        GLOBAL.get_or_init(Tracer::new)
    }

    /// The one branch every disabled-path call pays.
    #[inline]
    pub fn is_enabled(&self) -> bool {
        self.enabled.load(Ordering::Relaxed)
    }

    pub fn enable(&self) {
        self.enabled.store(true, Ordering::Relaxed);
    }

    pub fn disable(&self) {
        self.enabled.store(false, Ordering::Relaxed);
    }

    #[inline]
    fn now_ns(&self) -> u64 {
        // checked: never panics even if a caller-supplied Instant
        // predates the epoch (clamps to 0).
        Instant::now()
            .checked_duration_since(self.epoch)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0)
    }

    #[inline]
    fn instant_ns(&self, t: Instant) -> u64 {
        t.checked_duration_since(self.epoch)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0)
    }

    /// This thread's rings for this tracer, registering on first use.
    fn buf(&self) -> Arc<ThreadBuf> {
        TL_BUFS.with(|tl| {
            let mut v = tl.borrow_mut();
            if let Some((_, b)) = v.iter().find(|(id, _)| *id == self.tracer_id) {
                return b.clone();
            }
            let buf = Arc::new(ThreadBuf {
                thread: self.next_thread.fetch_add(1, Ordering::Relaxed),
                spans: Ring::new(SPAN_CAPACITY),
                convs: Ring::new(CONV_CAPACITY),
            });
            lock_recover(&self.bufs).push(buf.clone());
            v.push((self.tracer_id, buf.clone()));
            buf
        })
    }

    /// Record an instantaneous event under the current job context.
    #[inline]
    pub fn event(&self, name: &'static str, arg: u64) {
        if !self.is_enabled() {
            return;
        }
        let ctx = TL_CTX.with(Cell::get);
        self.event_with(name, ctx, arg);
    }

    /// Record an event for an explicit job (submit-side call sites that
    /// run before any worker context exists).
    #[inline]
    pub fn event_job(&self, name: &'static str, job_id: u64, job_kind: &'static str, arg: u64) {
        if !self.is_enabled() {
            return;
        }
        let mut ctx = TL_CTX.with(Cell::get);
        ctx.job_id = job_id;
        ctx.kind = job_kind;
        self.event_with(name, ctx, arg);
    }

    fn event_with(&self, name: &'static str, ctx: JobCtx, arg: u64) {
        let t = self.now_ns();
        let buf = self.buf();
        buf.spans.push(Span {
            name,
            phase: Phase::Event,
            t_start_ns: t,
            t_end_ns: t,
            id: self.next_span_id.fetch_add(1, Ordering::Relaxed),
            parent: current_parent(),
            thread: buf.thread,
            job_id: ctx.job_id,
            job_kind: ctx.kind,
            structure_hash: ctx.structure_hash,
            worker: ctx.worker,
            arg,
        })
    }

    /// Open a span; closed (and recorded) when the guard drops.
    /// Inert — no clock read, no ring touch — while disabled.
    #[inline]
    pub fn span(&self, name: &'static str) -> SpanGuard<'_> {
        if !self.is_enabled() {
            return SpanGuard { inner: None };
        }
        self.span_armed(name, 0)
    }

    /// Open a span with a per-name argument.
    #[inline]
    pub fn span_arg(&self, name: &'static str, arg: u64) -> SpanGuard<'_> {
        if !self.is_enabled() {
            return SpanGuard { inner: None };
        }
        self.span_armed(name, arg)
    }

    fn span_armed(&self, name: &'static str, arg: u64) -> SpanGuard<'_> {
        let id = self.next_span_id.fetch_add(1, Ordering::Relaxed);
        let parent = current_parent();
        push_parent(id);
        SpanGuard {
            inner: Some(OpenSpan {
                tracer: self,
                name,
                t_start_ns: self.now_ns(),
                id,
                parent,
                arg,
            }),
        }
    }

    /// Record an already-elapsed interval (e.g. queue wait measured by
    /// `Instant`s the engine captured before tracing was consulted).
    pub fn span_between(&self, name: &'static str, start: Instant, end: Instant, arg: u64) {
        if !self.is_enabled() {
            return;
        }
        let ctx = TL_CTX.with(Cell::get);
        let buf = self.buf();
        buf.spans.push(Span {
            name,
            phase: Phase::Span,
            t_start_ns: self.instant_ns(start),
            t_end_ns: self.instant_ns(end),
            id: self.next_span_id.fetch_add(1, Ordering::Relaxed),
            parent: current_parent(),
            thread: buf.thread,
            job_id: ctx.job_id,
            job_kind: ctx.kind,
            structure_hash: ctx.structure_hash,
            worker: ctx.worker,
            arg,
        });
    }

    fn push_conv(&self, mut rec: ConvRecord) {
        let ctx = TL_CTX.with(Cell::get);
        rec.t_ns = self.now_ns();
        rec.job_id = ctx.job_id;
        rec.job_kind = ctx.kind;
        rec.structure_hash = ctx.structure_hash;
        let buf = self.buf();
        rec.thread = buf.thread;
        buf.convs.push(rec);
    }

    /// Collect everything published so far across all threads.  Safe
    /// to call while recording continues (readers see a prefix).
    pub fn snapshot(&self) -> TraceSnapshot {
        let mut snap = TraceSnapshot::default();
        let bufs = lock_recover(&self.bufs);
        for b in bufs.iter() {
            b.spans.snapshot_into(&mut snap.spans);
            b.convs.snapshot_into(&mut snap.convs);
            snap.dropped += b.spans.dropped.load(Ordering::Relaxed)
                + b.convs.dropped.load(Ordering::Relaxed);
        }
        snap.spans.sort_by_key(|s| (s.t_start_ns, s.id));
        snap.convs.sort_by_key(|c| (c.t_ns, c.job_id));
        snap
    }
}

fn current_parent() -> u64 {
    let depth = TL_DEPTH.with(Cell::get);
    if depth == 0 {
        return 0;
    }
    let parents = TL_PARENTS.with(Cell::get);
    parents.get(depth - 1).copied().unwrap_or(0)
}

fn push_parent(id: u64) {
    let depth = TL_DEPTH.with(Cell::get);
    if depth < PARENT_DEPTH {
        let mut parents = TL_PARENTS.with(Cell::get);
        if let Some(slot) = parents.get_mut(depth) {
            *slot = id;
        }
        TL_PARENTS.with(|p| p.set(parents));
    }
    // depth keeps counting past the stack so pops stay balanced
    TL_DEPTH.with(|d| d.set(depth + 1));
}

fn pop_parent() {
    TL_DEPTH.with(|d| d.set(d.get().saturating_sub(1)));
}

struct OpenSpan<'a> {
    tracer: &'a Tracer,
    name: &'static str,
    t_start_ns: u64,
    id: u64,
    parent: u64,
    arg: u64,
}

/// RAII handle closing a span on drop.  When tracing was disabled at
/// open time the guard is a no-op shell.
pub struct SpanGuard<'a> {
    inner: Option<OpenSpan<'a>>,
}

impl Drop for SpanGuard<'_> {
    fn drop(&mut self) {
        let Some(open) = self.inner.take() else {
            return;
        };
        pop_parent();
        let ctx = TL_CTX.with(Cell::get);
        let buf = open.tracer.buf();
        buf.spans.push(Span {
            name: open.name,
            phase: Phase::Span,
            t_start_ns: open.t_start_ns,
            t_end_ns: open.tracer.now_ns(),
            id: open.id,
            parent: open.parent,
            thread: buf.thread,
            job_id: ctx.job_id,
            job_kind: ctx.kind,
            structure_hash: ctx.structure_hash,
            worker: ctx.worker,
            arg: open.arg,
        });
    }
}

// ---------------------------------------------------------------------
// job context
// ---------------------------------------------------------------------

/// Job attribution inherited by every span/event a thread records.
#[derive(Clone, Copy, Debug)]
pub struct JobCtx {
    pub job_id: u64,
    pub kind: &'static str,
    pub structure_hash: u64,
    pub worker: u32,
}

impl JobCtx {
    pub const NONE: JobCtx = JobCtx {
        job_id: 0,
        kind: "",
        structure_hash: 0,
        worker: u32::MAX,
    };
}

/// Restores the previous context on drop (job scopes nest under fused
/// batches).
pub struct JobScope {
    prev: JobCtx,
}

impl Drop for JobScope {
    fn drop(&mut self) {
        TL_CTX.with(|c| c.set(self.prev));
    }
}

/// Enter a job scope on this thread.  Cheap enough to run even with
/// tracing disabled (two `Cell` moves, no branch on the flag) so the
/// engine does not need to special-case it.
pub fn job_scope(job_id: u64, kind: &'static str, structure_hash: u64, worker: u32) -> JobScope {
    let prev = TL_CTX.with(Cell::get);
    TL_CTX.with(|c| {
        c.set(JobCtx {
            job_id,
            kind,
            structure_hash,
            worker,
        })
    });
    JobScope { prev }
}

// ---------------------------------------------------------------------
// convergence telemetry
// ---------------------------------------------------------------------

/// Stack-local per-solve accumulator for the Krylov kernels.  All
/// methods are branch-gated on the flag sampled at construction; the
/// disabled cost inside an iteration loop is one predictable branch,
/// and nothing here allocates (L5-compatible by construction).
pub struct ConvergenceTrace {
    on: bool,
    name: &'static str,
    restarts: u32,
    broke: bool,
    break_iter: u64,
    hist_total: u64,
    history: [f64; HISTORY_RING],
}

impl ConvergenceTrace {
    /// Sample the global tracer's flag once for the whole solve.
    #[inline]
    pub fn new(name: &'static str) -> Self {
        ConvergenceTrace {
            on: Tracer::global().is_enabled(),
            name,
            restarts: 0,
            broke: false,
            break_iter: 0,
            hist_total: 0,
            history: [0.0; HISTORY_RING],
        }
    }

    /// Record one iteration's residual NORM.
    #[inline]
    pub fn record(&mut self, r_norm: f64) {
        if self.on {
            self.push_norm(r_norm);
        }
    }

    /// Record from a SQUARED norm; the sqrt happens only when tracing
    /// is on and only into the local ring — solver arithmetic is
    /// untouched.
    #[inline]
    pub fn record_sq(&mut self, rr: f64) {
        if self.on {
            self.push_norm(rr.sqrt());
        }
    }

    #[inline]
    fn push_norm(&mut self, r: f64) {
        let i = (self.hist_total as usize) % HISTORY_RING;
        if let Some(slot) = self.history.get_mut(i) {
            *slot = r;
        }
        self.hist_total += 1;
    }

    /// Mark a recurrence breakdown at iteration `iter`.
    #[inline]
    pub fn breakdown(&mut self, iter: usize) {
        if self.on && !self.broke {
            self.broke = true;
            self.break_iter = iter as u64;
            Tracer::global().event(names::KRYLOV_BREAKDOWN, iter as u64);
        }
    }

    /// Mark a basis restart (GMRES).
    #[inline]
    pub fn restart(&mut self) {
        if self.on {
            self.restarts += 1;
            Tracer::global().event(names::KRYLOV_RESTART, self.restarts as u64);
        }
    }

    /// Emit the solve's record.  No-op while disabled.
    pub fn finish(self, iters: usize, residual: f64, converged: bool) {
        self.finish_dist(iters, residual, converged, 0, 0)
    }

    /// Emit with distributed-communication deltas attached.
    pub fn finish_dist(
        self,
        iters: usize,
        residual: f64,
        converged: bool,
        reduce_rounds: u64,
        halo_bytes: u64,
    ) {
        if !self.on {
            return;
        }
        Tracer::global().push_conv(ConvRecord {
            name: self.name,
            t_ns: 0,
            thread: 0,
            job_id: 0,
            job_kind: "",
            structure_hash: 0,
            iters: iters as u64,
            residual,
            converged,
            breakdown: self.broke,
            restarts: self.restarts,
            reduce_rounds,
            halo_bytes,
            hist_total: self.hist_total,
            history: self.history,
        });
    }
}

// ---------------------------------------------------------------------
// free functions over the global tracer
// ---------------------------------------------------------------------

/// Is the process-wide tracer recording?
#[inline]
pub fn enabled() -> bool {
    Tracer::global().is_enabled()
}

/// Open a span on the global tracer.
#[inline]
pub fn span(name: &'static str) -> SpanGuard<'static> {
    Tracer::global().span(name)
}

/// Open a span with an argument on the global tracer.
#[inline]
pub fn span_arg(name: &'static str, arg: u64) -> SpanGuard<'static> {
    Tracer::global().span_arg(name, arg)
}

/// Record an instantaneous event on the global tracer.
#[inline]
pub fn event(name: &'static str, arg: u64) {
    Tracer::global().event(name, arg)
}

/// Record an event attributed to an explicit job id/kind.
#[inline]
pub fn event_job(name: &'static str, job_id: u64, job_kind: &'static str, arg: u64) {
    Tracer::global().event_job(name, job_id, job_kind, arg)
}

/// Record an already-elapsed interval on the global tracer.
#[inline]
pub fn span_between(name: &'static str, start: Instant, end: Instant, arg: u64) {
    Tracer::global().span_between(name, start, end, arg)
}

/// Unit tests that enable/disable the PROCESS-WIDE tracer must not
/// interleave (the harness runs `#[test]`s on parallel threads); they
/// serialize on this lock.  Integration tests are separate processes
/// and do not need it.
#[cfg(test)]
pub(crate) fn global_test_guard() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    lock_recover(&LOCK)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new();
        {
            let _g = t.span(names::JOB_EXEC);
            t.event(names::FACTOR_MISS, 1);
        }
        let snap = t.snapshot();
        assert!(snap.spans.is_empty());
        assert_eq!(snap.dropped, 0);
    }

    #[test]
    fn spans_nest_and_carry_parents() {
        let t = Tracer::new();
        t.enable();
        {
            let _outer = t.span(names::JOB_EXEC);
            let _inner = t.span(names::DIRECT_NUMERIC);
            t.event(names::FACTOR_MISS, 7);
        }
        t.disable();
        let snap = t.snapshot();
        assert_eq!(snap.spans.len(), 3);
        let outer = snap.spans.iter().find(|s| s.name == names::JOB_EXEC).unwrap();
        let inner = snap
            .spans
            .iter()
            .find(|s| s.name == names::DIRECT_NUMERIC)
            .unwrap();
        let ev = snap.spans.iter().find(|s| s.name == names::FACTOR_MISS).unwrap();
        assert_eq!(outer.parent, 0);
        assert_eq!(inner.parent, outer.id);
        assert_eq!(ev.parent, inner.id, "event nests under the open span");
        assert_eq!(ev.phase, Phase::Event);
        assert!(outer.t_end_ns >= inner.t_end_ns);
        assert_eq!(ev.arg, 7);
    }

    #[test]
    fn job_scope_attributes_and_restores() {
        let t = Tracer::new();
        t.enable();
        {
            let _scope = job_scope(42, "linear", 0xBEEF, 3);
            t.event(names::FACTOR_MISS, 0);
        }
        t.event(names::FACTOR_MISS, 0);
        t.disable();
        let snap = t.snapshot();
        let inside = snap.spans.first().unwrap();
        let outside = snap.spans.get(1).unwrap();
        assert_eq!(inside.job_id, 42);
        assert_eq!(inside.job_kind, "linear");
        assert_eq!(inside.structure_hash, 0xBEEF);
        assert_eq!(inside.worker, 3);
        assert_eq!(outside.job_id, 0, "scope restored on drop");
    }

    #[test]
    fn ring_overflow_drops_new_records_and_counts_them() {
        let r: Ring<u64> = Ring::new(4);
        for i in 0..10 {
            r.push(i);
        }
        let mut out = Vec::new();
        r.snapshot_into(&mut out);
        assert_eq!(out, vec![0, 1, 2, 3], "head preserved, tail dropped");
        assert_eq!(r.dropped.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn convergence_trace_rings_the_last_norms() {
        let _serial = global_test_guard();
        let t = Tracer::global();
        t.enable();
        let mut ct = ConvergenceTrace::new(names::KRYLOV_CG);
        for i in 0..(HISTORY_RING + 5) {
            ct.record(i as f64);
        }
        ct.finish(HISTORY_RING + 5, 1e-11, true);
        t.disable();
        let snap = t.snapshot();
        let rec = snap
            .convs
            .iter()
            .find(|c| c.name == names::KRYLOV_CG && c.iters == (HISTORY_RING + 5) as u64)
            .expect("conv record emitted");
        assert_eq!(rec.hist_total, (HISTORY_RING + 5) as u64);
        // slot 0 holds norm HISTORY_RING (wrapped), slot 4 the last
        assert_eq!(rec.history.first().copied().unwrap(), HISTORY_RING as f64);
        assert!(rec.converged);
    }

    #[test]
    fn concurrent_writers_publish_without_loss() {
        let t = Arc::new(Tracer::new());
        t.enable();
        const THREADS: usize = 8;
        const PER: usize = 500;
        let done = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..THREADS {
                let t = t.clone();
                let done = done.clone();
                s.spawn(move || {
                    for i in 0..PER {
                        t.event(names::JOB_SUBMIT, i as u64);
                    }
                    done.fetch_add(1, Ordering::SeqCst);
                });
            }
        });
        assert_eq!(done.load(Ordering::SeqCst), THREADS);
        let snap = t.snapshot();
        assert_eq!(snap.spans.len(), THREADS * PER);
        assert_eq!(snap.dropped, 0);
    }
}
