//! Trace exporters: chrome://tracing JSON (loads in Perfetto), compact
//! JSONL, a dependency-free chrome-trace schema validator (used by the
//! test suite and by `rsla trace --check`), and the human-readable
//! [`TraceSummary`] printed at shutdown.
//!
//! All aggregation runs over `BTreeMap`s so the output order is
//! deterministic (L3) and the exported files diff cleanly run-to-run
//! modulo timestamps.

use std::collections::BTreeMap;
use std::fmt;

use super::{ConvRecord, Phase, Span, TraceSnapshot, HISTORY_RING};

// ---------------------------------------------------------------------
// serialization helpers
// ---------------------------------------------------------------------

fn escape_into(out: &mut String, s: &str) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
}

/// JSON has no NaN/inf; clamp non-finite floats to null.
fn push_f64(out: &mut String, v: f64) {
    if v.is_finite() {
        out.push_str(&format!("{v:e}"));
    } else {
        out.push_str("null");
    }
}

fn push_common_args(out: &mut String, job_id: u64, job_kind: &str, hash: u64, worker: u32) {
    out.push_str(&format!("\"job\":{job_id}"));
    out.push_str(",\"kind\":\"");
    escape_into(out, job_kind);
    out.push('"');
    out.push_str(&format!(",\"structure_hash\":\"{hash:#018x}\""));
    if worker != u32::MAX {
        out.push_str(&format!(",\"worker\":{worker}"));
    }
}

fn push_span_event(out: &mut String, s: &Span) {
    out.push_str("{\"name\":\"");
    escape_into(out, s.name);
    out.push_str("\",\"ph\":\"");
    match s.phase {
        Phase::Span => out.push('X'),
        Phase::Event => out.push('i'),
    }
    out.push_str(&format!(
        "\",\"ts\":{:.3},\"pid\":0,\"tid\":{}",
        s.t_start_ns as f64 / 1_000.0,
        s.thread
    ));
    match s.phase {
        Phase::Span => {
            let dur = s.t_end_ns.saturating_sub(s.t_start_ns);
            out.push_str(&format!(",\"dur\":{:.3}", dur as f64 / 1_000.0));
        }
        Phase::Event => out.push_str(",\"s\":\"t\""),
    }
    out.push_str(",\"args\":{");
    push_common_args(out, s.job_id, s.job_kind, s.structure_hash, s.worker);
    out.push_str(&format!(
        ",\"span_id\":{},\"parent\":{},\"arg\":{}}}}}",
        s.id, s.parent, s.arg
    ));
}

/// The ring holds the LAST `min(total, HISTORY_RING)` norms with the
/// oldest at `total % HISTORY_RING`; unwrap to chronological order.
fn history_chronological(rec: &ConvRecord) -> Vec<f64> {
    let kept = (rec.hist_total as usize).min(HISTORY_RING);
    let start = if (rec.hist_total as usize) > HISTORY_RING {
        (rec.hist_total as usize) % HISTORY_RING
    } else {
        0
    };
    let mut out = Vec::with_capacity(kept);
    for k in 0..kept {
        if let Some(v) = rec.history.get((start + k) % HISTORY_RING) {
            out.push(*v);
        }
    }
    out
}

fn push_conv_event(out: &mut String, c: &ConvRecord) {
    out.push_str("{\"name\":\"");
    escape_into(out, c.name);
    out.push_str(&format!(
        "\",\"ph\":\"i\",\"s\":\"t\",\"ts\":{:.3},\"pid\":0,\"tid\":{}",
        c.t_ns as f64 / 1_000.0,
        c.thread
    ));
    out.push_str(",\"args\":{");
    push_common_args(out, c.job_id, c.job_kind, c.structure_hash, u32::MAX);
    out.push_str(&format!(
        ",\"iters\":{},\"converged\":{},\"breakdown\":{},\"restarts\":{},\
         \"reduce_rounds\":{},\"halo_bytes\":{},\"residual\":",
        c.iters, c.converged, c.breakdown, c.restarts, c.reduce_rounds, c.halo_bytes
    ));
    push_f64(out, c.residual);
    out.push_str(&format!(",\"history_total\":{},\"history_tail\":[", c.hist_total));
    for (k, v) in history_chronological(c).iter().enumerate() {
        if k > 0 {
            out.push(',');
        }
        push_f64(out, *v);
    }
    out.push_str("]}}");
}

/// Serialize a snapshot in chrome://tracing object format; the result
/// loads directly in Perfetto / `chrome://tracing`.
pub fn chrome_trace_json(snap: &TraceSnapshot) -> String {
    let mut out = String::with_capacity(128 * (snap.spans.len() + snap.convs.len()) + 64);
    out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
    let mut first = true;
    for s in &snap.spans {
        if !first {
            out.push(',');
        }
        first = false;
        out.push('\n');
        push_span_event(&mut out, s);
    }
    for c in &snap.convs {
        if !first {
            out.push(',');
        }
        first = false;
        out.push('\n');
        push_conv_event(&mut out, c);
    }
    out.push_str("\n]}\n");
    out
}

/// Compact JSONL: one record per line (`type` is `span`, `event`, or
/// `conv`), times in integer nanoseconds — the machine-diffable form.
pub fn jsonl(snap: &TraceSnapshot) -> String {
    let mut out = String::with_capacity(96 * (snap.spans.len() + snap.convs.len()));
    for s in &snap.spans {
        let ty = match s.phase {
            Phase::Span => "span",
            Phase::Event => "event",
        };
        out.push_str(&format!(
            "{{\"type\":\"{ty}\",\"name\":\"{}\",\"t0\":{},\"t1\":{},\"id\":{},\"parent\":{},\
             \"thread\":{},\"job\":{},\"kind\":\"{}\",\"hash\":{},\"worker\":{},\"arg\":{}}}\n",
            s.name,
            s.t_start_ns,
            s.t_end_ns,
            s.id,
            s.parent,
            s.thread,
            s.job_id,
            s.job_kind,
            s.structure_hash,
            s.worker,
            s.arg
        ));
    }
    for c in &snap.convs {
        out.push_str(&format!(
            "{{\"type\":\"conv\",\"name\":\"{}\",\"t\":{},\"thread\":{},\"job\":{},\
             \"kind\":\"{}\",\"iters\":{},\"residual\":",
            c.name, c.t_ns, c.thread, c.job_id, c.job_kind, c.iters
        ));
        push_f64(&mut out, c.residual);
        out.push_str(&format!(
            ",\"converged\":{},\"breakdown\":{},\"restarts\":{},\"reduce_rounds\":{},\
             \"halo_bytes\":{},\"history_total\":{},\"history_tail\":[",
            c.converged, c.breakdown, c.restarts, c.reduce_rounds, c.halo_bytes, c.hist_total
        ));
        for (k, v) in history_chronological(c).iter().enumerate() {
            if k > 0 {
                out.push(',');
            }
            push_f64(&mut out, *v);
        }
        out.push_str("]}\n");
    }
    out
}

// ---------------------------------------------------------------------
// chrome-trace schema validation (dependency-free)
// ---------------------------------------------------------------------

/// What a validated trace contained.
#[derive(Clone, Debug, Default)]
pub struct ChromeTraceStats {
    pub events: usize,
    /// `ph: "X"` complete spans.
    pub complete: usize,
    /// `ph: "i"` instant events.
    pub instants: usize,
    /// Distinct event names seen.
    pub names: std::collections::BTreeSet<String>,
    /// Distinct `args.kind` values seen (job kinds).
    pub kinds: std::collections::BTreeSet<String>,
}

enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get<'a>(&'a self, key: &str) -> Option<&'a Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    fn as_num(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn consume(&mut self, want: u8) -> Result<(), String> {
        self.skip_ws();
        match self.bump() {
            Some(c) if c == want => Ok(()),
            got => Err(format!(
                "expected '{}' at byte {}, got {:?}",
                want as char,
                self.pos,
                got.map(|b| b as char)
            )),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        for want in word.bytes() {
            if self.bump() != Some(want) {
                return Err(format!("malformed literal near byte {}", self.pos));
            }
        }
        Ok(value)
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => self.string().map(Json::Str),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number().map(Json::Num),
            other => Err(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.pos
            )),
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.consume(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.consume(b':')?;
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                got => {
                    return Err(format!(
                        "expected ',' or '}}' at byte {}, got {:?}",
                        self.pos,
                        got.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.consume(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                got => {
                    return Err(format!(
                        "expected ',' or ']' at byte {}, got {:?}",
                        self.pos,
                        got.map(|b| b as char)
                    ))
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.consume(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') | Some(b'f') => {}
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self
                                .bump()
                                .and_then(|b| (b as char).to_digit(16))
                                .ok_or_else(|| format!("bad \\u escape at byte {}", self.pos))?;
                            code = code * 16 + d;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    other => return Err(format!("bad escape {other:?} at byte {}", self.pos)),
                },
                Some(c) => out.push(c as char),
                None => return Err("unterminated string".to_string()),
            }
        }
    }

    fn number(&mut self) -> Result<f64, String> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        ) {
            self.pos += 1;
        }
        let text = self
            .bytes
            .get(start..self.pos)
            .and_then(|b| std::str::from_utf8(b).ok())
            .ok_or_else(|| format!("bad number at byte {start}"))?;
        text.parse::<f64>()
            .map_err(|e| format!("bad number \"{text}\" at byte {start}: {e}"))
    }
}

/// Parse `text` as chrome-trace JSON and check the event schema:
/// top-level object with a `traceEvents` array; every event has
/// string `name`/`ph`, numeric `ts`/`pid`/`tid`; `ph:"X"` events carry
/// a non-negative `dur`; `ph:"i"` events carry a scope `s`.
pub fn validate_chrome_trace(text: &str) -> Result<ChromeTraceStats, String> {
    let mut p = Parser {
        bytes: text.as_bytes(),
        pos: 0,
    };
    let doc = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing bytes after document at {}", p.pos));
    }
    let events = match doc.get("traceEvents") {
        Some(Json::Arr(items)) => items,
        Some(_) => return Err("traceEvents is not an array".to_string()),
        None => return Err("top-level object lacks traceEvents".to_string()),
    };
    let mut stats = ChromeTraceStats::default();
    for (i, ev) in events.iter().enumerate() {
        let name = ev
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing string name"))?;
        let ph = ev
            .get("ph")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("event {i}: missing string ph"))?;
        for key in ["ts", "pid", "tid"] {
            ev.get(key)
                .and_then(Json::as_num)
                .ok_or_else(|| format!("event {i} ({name}): missing numeric {key}"))?;
        }
        match ph {
            "X" => {
                let dur = ev
                    .get("dur")
                    .and_then(Json::as_num)
                    .ok_or_else(|| format!("event {i} ({name}): ph X without dur"))?;
                if dur < 0.0 {
                    return Err(format!("event {i} ({name}): negative dur"));
                }
                stats.complete += 1;
            }
            "i" => {
                ev.get("s")
                    .and_then(Json::as_str)
                    .ok_or_else(|| format!("event {i} ({name}): ph i without scope s"))?;
                stats.instants += 1;
            }
            "M" => {}
            other => return Err(format!("event {i} ({name}): unknown ph \"{other}\"")),
        }
        if let Some(kind) = ev.get("args").and_then(|a| a.get("kind")).and_then(Json::as_str) {
            if !kind.is_empty() {
                stats.kinds.insert(kind.to_string());
            }
        }
        stats.names.insert(name.to_string());
        stats.events += 1;
    }
    Ok(stats)
}

// ---------------------------------------------------------------------
// summary
// ---------------------------------------------------------------------

#[derive(Clone, Debug, Default)]
struct NameStat {
    count: u64,
    total_ns: u64,
    max_ns: u64,
    events: u64,
}

#[derive(Clone, Debug, Default)]
struct ConvStat {
    solves: u64,
    iters_total: u64,
    iters_max: u64,
    breakdowns: u64,
    unconverged: u64,
    reduce_rounds: u64,
    halo_bytes: u64,
}

/// Per-phase and per-kernel aggregates of one snapshot — the shutdown
/// report `serve-sim` prints next to its hit-rate stats.
#[derive(Clone, Debug, Default)]
pub struct TraceSummary {
    spans: BTreeMap<&'static str, NameStat>,
    /// `job.exec` stats keyed by job kind.
    kinds: BTreeMap<&'static str, NameStat>,
    convs: BTreeMap<&'static str, ConvStat>,
    pub total_records: usize,
    pub threads: usize,
    pub dropped: u64,
}

impl TraceSummary {
    pub fn of(snap: &TraceSnapshot) -> TraceSummary {
        let mut sum = TraceSummary {
            total_records: snap.spans.len() + snap.convs.len(),
            dropped: snap.dropped,
            ..TraceSummary::default()
        };
        let mut threads = std::collections::BTreeSet::new();
        for s in &snap.spans {
            threads.insert(s.thread);
            let stat = sum.spans.entry(s.name).or_default();
            match s.phase {
                Phase::Span => {
                    let d = s.t_end_ns.saturating_sub(s.t_start_ns);
                    stat.count += 1;
                    stat.total_ns += d;
                    stat.max_ns = stat.max_ns.max(d);
                }
                Phase::Event => stat.events += 1,
            }
            if s.name == super::names::JOB_EXEC && !s.job_kind.is_empty() {
                let k = sum.kinds.entry(s.job_kind).or_default();
                let d = s.t_end_ns.saturating_sub(s.t_start_ns);
                k.count += 1;
                k.total_ns += d;
                k.max_ns = k.max_ns.max(d);
            }
        }
        for c in &snap.convs {
            threads.insert(c.thread);
            let stat = sum.convs.entry(c.name).or_default();
            stat.solves += 1;
            stat.iters_total += c.iters;
            stat.iters_max = stat.iters_max.max(c.iters);
            stat.breakdowns += u64::from(c.breakdown);
            stat.unconverged += u64::from(!c.converged);
            stat.reduce_rounds += c.reduce_rounds;
            stat.halo_bytes += c.halo_bytes;
        }
        sum.threads = threads.len();
        sum
    }

    /// Count of closed spans recorded under `name`.
    pub fn span_count(&self, name: &str) -> u64 {
        self.spans.get(name).map(|s| s.count).unwrap_or(0)
    }

    /// Count of instant events recorded under `name`.
    pub fn event_count(&self, name: &str) -> u64 {
        self.spans.get(name).map(|s| s.events).unwrap_or(0)
    }

    /// Job kinds that completed at least one `job.exec` span.
    pub fn kinds_seen(&self) -> Vec<&'static str> {
        self.kinds.keys().copied().collect()
    }

    /// Total solves recorded by convergence telemetry under `name`.
    pub fn conv_count(&self, name: &str) -> u64 {
        self.convs.get(name).map(|c| c.solves).unwrap_or(0)
    }
}

fn ms(ns: u64) -> f64 {
    ns as f64 / 1.0e6
}

impl fmt::Display for TraceSummary {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(
            f,
            "trace summary: {} records across {} threads ({} dropped)",
            self.total_records, self.threads, self.dropped
        )?;
        if !self.spans.is_empty() {
            writeln!(
                f,
                "  {:<26} {:>8} {:>8} {:>12} {:>10}",
                "span", "count", "events", "total ms", "max ms"
            )?;
            for (name, s) in &self.spans {
                writeln!(
                    f,
                    "  {:<26} {:>8} {:>8} {:>12.3} {:>10.3}",
                    name,
                    s.count,
                    s.events,
                    ms(s.total_ns),
                    ms(s.max_ns)
                )?;
            }
        }
        if !self.kinds.is_empty() {
            writeln!(f, "  job.exec by kind:")?;
            for (kind, s) in &self.kinds {
                writeln!(
                    f,
                    "    {:<24} {:>8} {:>21.3} {:>10.3}",
                    kind,
                    s.count,
                    ms(s.total_ns),
                    ms(s.max_ns)
                )?;
            }
        }
        if !self.convs.is_empty() {
            writeln!(f, "  convergence:")?;
            for (name, c) in &self.convs {
                writeln!(
                    f,
                    "    {:<24} solves={} iters(total={} max={}) breakdowns={} unconverged={} \
                     rounds={} halo_bytes={}",
                    name,
                    c.solves,
                    c.iters_total,
                    c.iters_max,
                    c.breakdowns,
                    c.unconverged,
                    c.reduce_rounds,
                    c.halo_bytes
                )?;
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::{names, ConvergenceTrace, Tracer};
    use super::*;

    fn sample_snapshot() -> TraceSnapshot {
        let t = Tracer::new();
        t.enable();
        {
            let _scope = super::super::job_scope(9, "linear", 0xABCD, 1);
            let _g = t.span(names::JOB_EXEC);
            t.event(names::FACTOR_MISS, 0);
            let _s = t.span_arg(names::DIRECT_NUMERIC, 3);
        }
        t.snapshot()
    }

    #[test]
    fn chrome_export_validates_and_reports_names() {
        let snap = sample_snapshot();
        let json = chrome_trace_json(&snap);
        let stats = validate_chrome_trace(&json).expect("schema-valid trace");
        assert_eq!(stats.events, 3);
        assert_eq!(stats.complete, 2);
        assert_eq!(stats.instants, 1);
        assert!(stats.names.contains(names::JOB_EXEC));
        assert!(stats.names.contains(names::FACTOR_MISS));
        assert!(stats.kinds.contains("linear"));
    }

    #[test]
    fn jsonl_has_one_line_per_record() {
        let snap = sample_snapshot();
        let text = jsonl(&snap);
        assert_eq!(text.lines().count(), 3);
        assert!(text.lines().all(|l| l.starts_with('{') && l.ends_with('}')));
    }

    #[test]
    fn conv_records_export_with_history_tail() {
        let _serial = super::super::global_test_guard();
        let t = Tracer::global();
        t.enable();
        let mut ct = ConvergenceTrace::new(names::KRYLOV_BICGSTAB);
        ct.record(3.0);
        ct.record(1.5);
        ct.finish(2, 1.5, false);
        t.disable();
        let snap = t.snapshot();
        let json = chrome_trace_json(&snap);
        let stats = validate_chrome_trace(&json).expect("valid");
        assert!(stats.names.contains(names::KRYLOV_BICGSTAB));
        let sum = TraceSummary::of(&snap);
        assert!(sum.conv_count(names::KRYLOV_BICGSTAB) >= 1);
        assert!(json.contains("\"history_tail\":[3e0,1.5e0]"));
    }

    #[test]
    fn validator_rejects_malformed_documents() {
        assert!(validate_chrome_trace("[]").is_err(), "array top level lacks traceEvents");
        assert!(validate_chrome_trace("{\"traceEvents\":[{}]}").is_err());
        assert!(validate_chrome_trace(
            "{\"traceEvents\":[{\"name\":\"a.b\",\"ph\":\"X\",\"ts\":1,\"pid\":0,\"tid\":0}]}"
        )
        .is_err(), "complete event without dur");
        assert!(validate_chrome_trace(
            "{\"traceEvents\":[{\"name\":\"a.b\",\"ph\":\"X\",\"ts\":1,\"pid\":0,\"tid\":0,\"dur\":2}]}"
        )
        .is_ok());
        assert!(validate_chrome_trace("{\"traceEvents\":[").is_err());
    }

    #[test]
    fn summary_displays_without_panicking() {
        let snap = sample_snapshot();
        let sum = TraceSummary::of(&snap);
        assert_eq!(sum.span_count(names::JOB_EXEC), 1);
        assert_eq!(sum.event_count(names::FACTOR_MISS), 1);
        assert_eq!(sum.kinds_seen(), vec!["linear"]);
        let text = format!("{sum}");
        assert!(text.contains("job.exec"));
        assert!(text.contains("linear"));
    }
}
