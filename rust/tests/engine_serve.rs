//! End-to-end suite for the solve engine: every [`JobKind`] executes
//! through `Engine::submit`, multi-RHS fusion is bitwise-identical to
//! per-request solves (the acceptance pin), pattern-affinity routing
//! measurably beats round-robin on shard warmth, and priority ordering
//! holds inside a scheduling window.

use std::sync::{Arc, Mutex};

use rsla::backend::{Dispatcher, SolveOpts};
use rsla::distributed::{DSparseTensor, DistIterOpts, PartitionStrategy};
use rsla::eigen::LobpcgOpts;
use rsla::engine::{
    BatchPolicy, Engine, EngineConfig, JobKind, JobOutput, JobSpec, Priority, SubmitOpts,
};
use rsla::nonlinear::{examples::QuadPoisson, NewtonOpts, Residual};
use rsla::sparse::graphs::random_nonsymmetric;
use rsla::sparse::poisson::poisson2d;
use rsla::util::{self, Prng};

fn engine(workers: usize, fuse: BatchPolicy, affinity: bool) -> Engine {
    Engine::start(
        Arc::new(Dispatcher::new(None)),
        EngineConfig {
            workers,
            fuse,
            affinity,
            ..Default::default()
        },
    )
}

fn no_fusion() -> BatchPolicy {
    BatchPolicy {
        max_batch: 1,
        window: std::time::Duration::from_millis(1),
    }
}

// ---------------------------------------------------------------------
// Acceptance: all six JobKinds execute through Engine::submit
// ---------------------------------------------------------------------

#[test]
fn all_six_jobkinds_execute_through_submit() {
    let e = engine(2, BatchPolicy::default(), true);
    let sys = poisson2d(8, None);
    let n = 64;
    let mut rng = Prng::new(3);

    // Linear
    let b = rng.normal_vec(n);
    let r = e
        .submit(JobSpec::Linear {
            matrix: sys.matrix.clone(),
            b: b.clone(),
            opts: SolveOpts::default(),
        })
        .unwrap()
        .wait();
    assert_eq!(r.kind, JobKind::Linear);
    match r.outcome.unwrap() {
        JobOutput::Linear(out) => {
            assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-8)
        }
        _ => panic!("linear job produced wrong output family"),
    }

    // MultiRhs
    let bs: Vec<Vec<f64>> = (0..3).map(|_| rng.normal_vec(n)).collect();
    let r = e
        .submit(JobSpec::MultiRhs {
            matrix: sys.matrix.clone(),
            bs: bs.clone(),
            opts: SolveOpts::default(),
        })
        .unwrap()
        .wait();
    assert_eq!(r.kind, JobKind::MultiRhs);
    match r.outcome.unwrap() {
        JobOutput::MultiRhs(outs) => {
            assert_eq!(outs.len(), 3);
            for (out, b) in outs.iter().zip(&bs) {
                assert!(util::rel_l2(&sys.matrix.matvec(&out.x), b) < 1e-8);
            }
        }
        _ => panic!("multi-rhs job produced wrong output family"),
    }

    // Nonlinear
    let res = QuadPoisson {
        a: sys.matrix.clone(),
        f: vec![1.0; n],
    };
    let probe = QuadPoisson {
        a: sys.matrix.clone(),
        f: vec![1.0; n],
    };
    let r = e
        .submit(JobSpec::Nonlinear {
            residual: Box::new(res),
            u0: vec![0.0; n],
            opts: NewtonOpts::default(),
        })
        .unwrap()
        .wait();
    assert_eq!(r.kind, JobKind::Nonlinear);
    match r.outcome.unwrap() {
        JobOutput::Nonlinear(nl) => {
            assert!(nl.converged, "Newton did not converge through the engine");
            let mut fu = vec![0.0; n];
            probe.eval(&nl.u, &mut fu);
            assert!(util::norm2(&fu) < 1e-8);
        }
        _ => panic!("nonlinear job produced wrong output family"),
    }

    // Eig
    let r = e
        .submit(JobSpec::Eig {
            matrix: sys.matrix.clone(),
            k: 2,
            opts: LobpcgOpts::default(),
        })
        .unwrap()
        .wait();
    assert_eq!(r.kind, JobKind::Eig);
    match r.outcome.unwrap() {
        JobOutput::Eig(eig) => {
            assert_eq!(eig.values.len(), 2);
            assert!(eig.values[0] > 0.0 && eig.values[0] <= eig.values[1]);
        }
        _ => panic!("eig job produced wrong output family"),
    }

    // Adjoint: one factorization serves forward + transpose; verify on
    // a NONsymmetric matrix so the transpose is observable.
    let a = random_nonsymmetric(&mut rng, 30, 3);
    let b = rng.normal_vec(30);
    let gy = rng.normal_vec(30);
    let r = e
        .submit(JobSpec::Adjoint {
            matrix: a.clone(),
            b: b.clone(),
            gy: gy.clone(),
            opts: SolveOpts::default(),
        })
        .unwrap()
        .wait();
    assert_eq!(r.kind, JobKind::Adjoint);
    match r.outcome.unwrap() {
        JobOutput::Adjoint { x, lambda } => {
            assert!(util::rel_l2(&a.matvec(&x), &b) < 1e-8);
            let mut aty = vec![0.0; 30];
            a.spmv_t(&lambda, &mut aty);
            assert!(util::rel_l2(&aty, &gy) < 1e-8);
        }
        _ => panic!("adjoint job produced wrong output family"),
    }

    // Dist: the worker launches and manages the rank team.
    let t = DSparseTensor::from_global(&sys.matrix, None, 2, PartitionStrategy::Contiguous)
        .unwrap();
    let b = rng.normal_vec(n);
    let r = e
        .submit(JobSpec::Dist {
            tensor: t,
            b: b.clone(),
            opts: DistIterOpts::default(),
        })
        .unwrap()
        .wait();
    assert_eq!(r.kind, JobKind::Dist);
    match r.outcome.unwrap() {
        JobOutput::Dist { x, reports } => {
            assert_eq!(reports.len(), 2);
            assert!(reports.iter().all(|r| r.converged));
            assert!(util::rel_l2(&sys.matrix.matvec(&x), &b) < 1e-6);
        }
        _ => panic!("dist job produced wrong output family"),
    }

    // every kind showed up in the per-kind histograms
    let stats = e.stats();
    for k in &stats.kinds {
        assert!(k.count >= 1, "kind {:?} never recorded a latency", k.kind);
    }
    assert_eq!(stats.queue_depth, 0);
    e.shutdown();
}

// ---------------------------------------------------------------------
// Acceptance: multi-RHS fusion output is bitwise-identical to
// per-request solves
// ---------------------------------------------------------------------

#[test]
fn fused_batch_is_bitwise_identical_to_per_request_solves() {
    let sys = poisson2d(9, None);
    let n = 81;
    let mut rng = Prng::new(5);
    let bs: Vec<Vec<f64>> = (0..6).map(|_| rng.normal_vec(n)).collect();

    // fusion ON: submit the burst before waiting so the window groups it
    let fused_engine = engine(
        1,
        BatchPolicy {
            max_batch: 16,
            window: std::time::Duration::from_millis(50),
        },
        true,
    );
    let tickets: Vec<_> = bs
        .iter()
        .map(|b| {
            fused_engine
                .submit(JobSpec::Linear {
                    matrix: sys.matrix.clone(),
                    b: b.clone(),
                    opts: SolveOpts::default(),
                })
                .unwrap()
        })
        .collect();
    let mut fused_xs = Vec::new();
    let mut max_batch_size = 0;
    for t in tickets {
        let r = t.wait();
        max_batch_size = max_batch_size.max(r.batch_size);
        match r.outcome.unwrap() {
            JobOutput::Linear(out) => fused_xs.push(out.x),
            _ => panic!("wrong output family"),
        }
    }
    assert!(
        max_batch_size >= 2,
        "burst of identical matrices never fused (max batch size {max_batch_size})"
    );

    // fusion OFF: same requests, strictly per-request
    let solo_engine = engine(1, no_fusion(), true);
    for (b, fused_x) in bs.iter().zip(&fused_xs) {
        let r = solo_engine
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: b.clone(),
                opts: SolveOpts::default(),
            })
            .unwrap()
            .wait();
        assert_eq!(r.batch_size, 1);
        match r.outcome.unwrap() {
            JobOutput::Linear(out) => {
                assert_eq!(
                    &out.x, fused_x,
                    "fused solve diverged bitwise from the per-request solve"
                );
            }
            _ => panic!("wrong output family"),
        }
    }
    fused_engine.shutdown();
    solo_engine.shutdown();
}

#[test]
fn multi_rhs_job_matches_individual_linear_jobs_bitwise() {
    let sys = poisson2d(7, None);
    let n = 49;
    let mut rng = Prng::new(6);
    let bs: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(n)).collect();
    let e = engine(1, no_fusion(), true);
    let multi = match e
        .submit(JobSpec::MultiRhs {
            matrix: sys.matrix.clone(),
            bs: bs.clone(),
            opts: SolveOpts::default(),
        })
        .unwrap()
        .wait()
        .outcome
        .unwrap()
    {
        JobOutput::MultiRhs(outs) => outs,
        _ => panic!("wrong output family"),
    };
    for (b, m) in bs.iter().zip(&multi) {
        let solo = match e
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: b.clone(),
                opts: SolveOpts::default(),
            })
            .unwrap()
            .wait()
            .outcome
            .unwrap()
        {
            JobOutput::Linear(out) => out,
            _ => panic!("wrong output family"),
        };
        assert_eq!(m.x, solo.x, "MultiRhs diverged from per-rhs Linear jobs");
    }
    e.shutdown();
}

// ---------------------------------------------------------------------
// Acceptance: pattern-affinity routing beats round-robin on shard
// warmth (deterministic counter version; the latency version lives in
// benches/serve_mixed.rs)
// ---------------------------------------------------------------------

fn run_sequential_same_pattern(affinity: bool, jobs: usize) -> (Engine, rsla::engine::EngineStats) {
    let e = engine(2, no_fusion(), affinity);
    let sys = poisson2d(10, None);
    let mut rng = Prng::new(9);
    for _ in 0..jobs {
        // sequential submit→wait so every job is routed alone
        let r = e
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: rng.normal_vec(100),
                opts: SolveOpts::default(),
            })
            .unwrap()
            .wait();
        r.outcome.unwrap();
    }
    let stats = e.stats();
    (e, stats)
}

#[test]
fn affinity_routes_same_pattern_to_the_warm_shard() {
    let (e, stats) = run_sequential_same_pattern(true, 6);
    // one cold factorization total: every later job found its shard warm
    assert_eq!(stats.cache.misses, 1, "affinity must factor exactly once");
    assert_eq!(stats.cache.hits_numeric, 5);
    assert_eq!(
        e.metrics.get("factor_cache.cross_shard_miss"),
        0,
        "affinity routing must never send a warm pattern to a cold shard"
    );
    assert_eq!(stats.affinity_misses, 1, "only the first routing is cold");
    assert_eq!(stats.affinity_hits, 5);
    e.shutdown();
}

#[test]
fn round_robin_pays_one_cold_factorization_per_shard() {
    let (e, stats) = run_sequential_same_pattern(false, 6);
    // rr over 2 workers: BOTH shards factor the same pattern once
    assert_eq!(
        stats.cache.misses, 2,
        "round-robin must go cold once per shard"
    );
    assert_eq!(stats.cache.hits_numeric, 4);
    assert!(
        e.metrics.get("factor_cache.cross_shard_miss") >= 1,
        "round-robin must be caught routing a warm pattern to a cold shard"
    );
    e.shutdown();
}

#[test]
fn affinity_beats_round_robin_on_hit_rate() {
    let (ea, aff) = run_sequential_same_pattern(true, 6);
    let (er, rnd) = run_sequential_same_pattern(false, 6);
    assert!(
        aff.cache_hit_rate() > rnd.cache_hit_rate(),
        "affinity hit rate {:.2} must beat round-robin {:.2}",
        aff.cache_hit_rate(),
        rnd.cache_hit_rate()
    );
    ea.shutdown();
    er.shutdown();
}

// ---------------------------------------------------------------------
// Scheduling order: priority classes inside one window
// ---------------------------------------------------------------------

#[test]
fn priority_orders_jobs_within_a_window() {
    // one worker, a long window: Low/Normal/High submitted back-to-back
    // land in one scheduling window and must execute High-first.  Three
    // distinct patterns keep them from fusing into one unit.
    let e = engine(
        1,
        BatchPolicy {
            max_batch: 8,
            window: std::time::Duration::from_millis(100),
        },
        true,
    );
    let order: Arc<Mutex<Vec<&'static str>>> = Arc::new(Mutex::new(Vec::new()));
    let (done_tx, done_rx) = std::sync::mpsc::channel::<()>();
    let mut rng = Prng::new(12);
    for (label, g, priority) in [
        ("low", 6usize, Priority::Low),
        ("normal", 7, Priority::Normal),
        ("high", 8, Priority::High),
    ] {
        let sys = poisson2d(g, None);
        let b = rng.normal_vec(g * g);
        let order = order.clone();
        let done = done_tx.clone();
        e.submit_with_reply(
            JobSpec::Linear {
                matrix: sys.matrix,
                b,
                opts: SolveOpts::default(),
            },
            SubmitOpts {
                priority,
                deadline: None,
            },
            Box::new(move |r| {
                r.outcome.unwrap();
                order.lock().unwrap().push(label);
                let _ = done.send(());
            }),
        )
        .unwrap();
    }
    for _ in 0..3 {
        done_rx
            .recv_timeout(std::time::Duration::from_secs(30))
            .expect("job reply");
    }
    let got = order.lock().unwrap().clone();
    assert_eq!(
        got,
        vec!["high", "normal", "low"],
        "priority classes must execute high-first within a window"
    );
    e.shutdown();
}
