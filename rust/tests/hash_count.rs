//! Pin: the engine computes each linear job's [`PatternKey`] exactly
//! once — in the scheduler — and threads it to the worker's factor-cache
//! shard instead of re-hashing on the serve path.
//!
//! `PatternKey::of` is a full O(nnz) pass, so a second hash per job is a
//! real regression; `rsla::sparse::key::pattern_hash_count` counts every
//! execution process-wide.  This lives in its own integration binary so
//! no other test's hashing races the counter.
//!
//! [`PatternKey`]: rsla::sparse::PatternKey

use std::sync::Arc;
use std::time::Duration;

use rsla::backend::{Dispatcher, SolveOpts};
use rsla::engine::{BatchPolicy, Engine, EngineConfig, JobSpec};
use rsla::sparse::key::pattern_hash_count;
use rsla::sparse::poisson::poisson2d;
use rsla::util::Prng;

#[test]
fn engine_hashes_each_linear_job_exactly_once() {
    let e = Engine::start(
        Arc::new(Dispatcher::new(None)),
        EngineConfig {
            workers: 1,
            fuse: BatchPolicy {
                max_batch: 1,
                window: Duration::from_millis(1),
            },
            affinity: true,
            ..Default::default()
        },
    );
    let sys = poisson2d(8, None);
    let n = sys.matrix.nrows;
    let mut rng = Prng::new(7);

    // One warm-up request so lazy setup (shard allocation, the first
    // factorization) is outside the measured window.
    let warm = e
        .submit(JobSpec::Linear {
            matrix: sys.matrix.clone(),
            b: rng.normal_vec(n),
            opts: SolveOpts::default(),
        })
        .expect("submit")
        .wait();
    assert!(warm.outcome.is_ok(), "warm-up solve failed");

    let baseline = pattern_hash_count();
    let k = 6u64;
    for _ in 0..k {
        let r = e
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: rng.normal_vec(n),
                opts: SolveOpts::default(),
            })
            .expect("submit")
            .wait();
        assert!(r.outcome.is_ok(), "solve failed");
    }
    let hashed = pattern_hash_count() - baseline;
    assert_eq!(
        hashed, k,
        "expected exactly one PatternKey::of per linear job ({k} jobs, {hashed} hashes)"
    );
    e.shutdown();
}

#[test]
fn round_robin_routing_still_hashes_exactly_once() {
    // With affinity off the scheduler has no routing use for the key,
    // but the worker's shard probe is keyed-only — so the count must
    // STAY one per job (the key rides the unit), not drop to zero and
    // not double on the serve path.
    let e = Engine::start(
        Arc::new(Dispatcher::new(None)),
        EngineConfig {
            workers: 2,
            fuse: BatchPolicy {
                max_batch: 1,
                window: Duration::from_millis(1),
            },
            affinity: false,
            ..Default::default()
        },
    );
    let sys = poisson2d(8, None);
    let n = sys.matrix.nrows;
    let mut rng = Prng::new(11);

    let warm = e
        .submit(JobSpec::Linear {
            matrix: sys.matrix.clone(),
            b: rng.normal_vec(n),
            opts: SolveOpts::default(),
        })
        .expect("submit")
        .wait();
    assert!(warm.outcome.is_ok(), "warm-up solve failed");

    let baseline = pattern_hash_count();
    let k = 6u64;
    for _ in 0..k {
        let r = e
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: rng.normal_vec(n),
                opts: SolveOpts::default(),
            })
            .expect("submit")
            .wait();
        assert!(r.outcome.is_ok(), "solve failed");
    }
    let hashed = pattern_hash_count() - baseline;
    assert_eq!(
        hashed, k,
        "round-robin routing must not change the one-hash-per-job pin ({k} jobs, {hashed} hashes)"
    );
    e.shutdown();
}
