//! Equivalence suite for the unified Krylov substrate.
//!
//! Two contracts are pinned here:
//!
//! 1. **Serial parity.**  The generic kernels under `NullComm` must
//!    reproduce the PRE-unification serial solvers: the reference
//!    loops below are frozen copies of the historical `iterative::cg`
//!    and `iterative::bicgstab` bodies, and the unified entry points
//!    must match them in iterate count and solution (1e-12 relative).
//! 2. **Distributed parity.**  The `dist_*` wrappers must match the
//!    serial solution on Poisson2D across 1/2/4 ranks — including the
//!    NEW distributed GMRES and MINRES paths and the transposed-halo
//!    adjoint — with the per-iteration reduction structure unchanged
//!    (standard CG: 2 rounds; pipelined: 1; pinned in
//!    `distributed::dist_solver` on `LocalComm`).

use std::sync::Arc;

use rsla::distributed::halo::distribute;
use rsla::distributed::partition::{partition, Partition, PartitionStrategy};
use rsla::distributed::{
    dist_bicgstab, dist_cg, dist_cg_ca, dist_cg_pipelined, dist_gmres, dist_minres,
    dist_solve_adjoint, run_ranks, CommBackend, DSparseTensor, DistCsr, DistIterOpts, ProcOpts,
    TransportKind,
};
use rsla::iterative::{bicgstab, cg, IterOpts, Jacobi, LinOp, Precond};
use rsla::krylov::CaCgOpts;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::sparse::{Coo, Csr};
use rsla::util::{self, axpy_inplace, dot, xpby_inplace, Prng};

/// Worker re-exec target for the process-backend tests below: spawned
/// rank-team children run this binary as
/// `krylov_equivalence proc_worker_entry --exact`.  The call exits the
/// process when the worker env is present and is a no-op otherwise.
#[test]
fn proc_worker_entry() {
    rsla::distributed::maybe_run_worker();
}

// ------------------------------------------------------------------
// 1. Frozen pre-refactor serial reference loops
// ------------------------------------------------------------------

/// The historical serial CG body, frozen verbatim (modulo MemTracker).
fn reference_cg(a: &dyn LinOp, b: &[f64], m: &dyn Precond, opts: &IterOpts) -> (Vec<f64>, usize, f64) {
    let n = a.nrows();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let mut z = vec![0.0; n];
    m.apply(&r, &mut z);
    let mut p = z.clone();
    let mut ap = vec![0.0; n];
    let mut rz = dot(&r, &z);
    let mut rr = dot(&r, &r);
    let tol2 = opts.tol * opts.tol;
    let mut iters = 0;
    while iters < opts.max_iters && rr > tol2 {
        a.apply(&p, &mut ap);
        let pap = dot(&p, &ap);
        if pap <= 0.0 || !pap.is_finite() {
            break;
        }
        let alpha = rz / pap;
        axpy_inplace(alpha, &p, &mut x);
        axpy_inplace(-alpha, &ap, &mut r);
        m.apply(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        xpby_inplace(&z, beta, &mut p);
        rz = rz_new;
        rr = dot(&r, &r);
        iters += 1;
    }
    (x, iters, rr.sqrt())
}

/// The historical serial BiCGStab body, frozen verbatim.
fn reference_bicgstab(
    a: &dyn LinOp,
    b: &[f64],
    m: &dyn Precond,
    opts: &IterOpts,
) -> (Vec<f64>, usize, f64) {
    let n = a.nrows();
    let mut x = vec![0.0; n];
    let mut r = b.to_vec();
    let r0 = b.to_vec();
    let mut p = vec![0.0; n];
    let mut v = vec![0.0; n];
    let mut s = vec![0.0; n];
    let mut t = vec![0.0; n];
    let mut phat = vec![0.0; n];
    let mut shat = vec![0.0; n];
    let mut rho = 1.0f64;
    let mut alpha = 1.0f64;
    let mut omega = 1.0f64;
    let mut rr = dot(&r, &r);
    let tol2 = opts.tol * opts.tol;
    let mut iters = 0;
    while iters < opts.max_iters && rr > tol2 {
        let rho_new = dot(&r0, &r);
        if rho_new == 0.0 {
            break;
        }
        if iters == 0 {
            p.copy_from_slice(&r);
        } else {
            let beta = (rho_new / rho) * (alpha / omega);
            for i in 0..n {
                p[i] = r[i] + beta * (p[i] - omega * v[i]);
            }
        }
        rho = rho_new;
        m.apply(&p, &mut phat);
        a.apply(&phat, &mut v);
        let r0v = dot(&r0, &v);
        if r0v == 0.0 {
            break;
        }
        alpha = rho / r0v;
        for i in 0..n {
            s[i] = r[i] - alpha * v[i];
        }
        let ss = dot(&s, &s);
        if ss <= tol2 {
            axpy_inplace(alpha, &phat, &mut x);
            rr = ss;
            iters += 1;
            break;
        }
        m.apply(&s, &mut shat);
        a.apply(&shat, &mut t);
        let tt = dot(&t, &t);
        if tt == 0.0 {
            break;
        }
        omega = dot(&t, &s) / tt;
        axpy_inplace(alpha, &phat, &mut x);
        axpy_inplace(omega, &shat, &mut x);
        for i in 0..n {
            r[i] = s[i] - omega * t[i];
        }
        rr = dot(&r, &r);
        iters += 1;
        if omega == 0.0 {
            break;
        }
    }
    (x, iters, rr.sqrt())
}

#[test]
fn unified_cg_under_null_comm_reproduces_pre_refactor_serial_cg() {
    for (g, seed) in [(16usize, 0u64), (24, 1), (32, 2)] {
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let mut rng = Prng::new(seed);
        let b = rng.normal_vec(g * g);
        let m = Jacobi::new(&sys.matrix).unwrap();
        let opts = IterOpts::default();
        let (x_ref, iters_ref, res_ref) = reference_cg(&sys.matrix, &b, &m, &opts);
        let got = cg(&sys.matrix, &b, &m, &opts, None);
        assert_eq!(
            got.iters, iters_ref,
            "g={g}: iterate count changed by the unification"
        );
        assert!(
            util::rel_l2(&got.x, &x_ref) < 1e-12,
            "g={g}: solution drifted from the pre-refactor serial CG"
        );
        assert!((got.residual - res_ref).abs() <= 1e-12 * (1.0 + res_ref));
    }
}

#[test]
fn tracing_does_not_move_the_fp_pins() {
    // rsla-trace records, it never reorders: the exact same CG run with
    // the global tracer ON must produce BITWISE-identical iterates and
    // the same iteration count as the untraced run.  Residual history
    // is sampled from values the kernel already computed (`record_sq`
    // defers the sqrt into the tracer), so no extra arithmetic enters
    // the loop.
    let sys = poisson2d(24, Some(&kappa_star(24)));
    let mut rng = Prng::new(11);
    let b = rng.normal_vec(24 * 24);
    let m = Jacobi::new(&sys.matrix).unwrap();
    let opts = IterOpts::default();

    let plain = cg(&sys.matrix, &b, &m, &opts, None);
    rsla::trace::Tracer::global().enable();
    let traced = cg(&sys.matrix, &b, &m, &opts, None);
    rsla::trace::Tracer::global().disable();

    assert_eq!(traced.iters, plain.iters, "tracing changed the iterate count");
    assert_eq!(
        traced.residual.to_bits(),
        plain.residual.to_bits(),
        "tracing changed the final residual bits"
    );
    for (i, (a, b)) in traced.x.iter().zip(&plain.x).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "tracing moved x[{i}]: {a:e} vs {b:e}"
        );
    }
    // and the traced run actually left a convergence record behind
    let snap = rsla::trace::Tracer::global().snapshot();
    assert!(
        snap.convs.iter().any(|c| c.iters == plain.iters as u64),
        "traced CG run left no convergence record"
    );
}

#[test]
fn unified_bicgstab_under_null_comm_reproduces_pre_refactor_serial() {
    let mut rng = Prng::new(7);
    let a = rsla::sparse::graphs::random_nonsymmetric(&mut rng, 120, 5);
    let b = rng.normal_vec(120);
    let m = Jacobi::new(&a).unwrap();
    let opts = IterOpts::default();
    let (x_ref, iters_ref, _) = reference_bicgstab(&a, &b, &m, &opts);
    let got = bicgstab(&a, &b, &m, &opts, None);
    assert_eq!(got.iters, iters_ref);
    assert!(util::rel_l2(&got.x, &x_ref) < 1e-12);
}

// ------------------------------------------------------------------
// 2. Distributed parity at 1/2/4 ranks
// ------------------------------------------------------------------

fn dist_setup(g: usize, nparts: usize, shift: f64) -> (Csr, Partition, Arc<Vec<DistCsr>>) {
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let a = if shift == 0.0 {
        sys.matrix.clone()
    } else {
        let n = g * g;
        let mut coo = Coo::with_capacity(n, n, sys.matrix.nnz());
        for r in 0..n {
            let (cols, vals) = sys.matrix.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(r, *c, if *c == r { v - shift } else { *v });
            }
        }
        coo.to_csr()
    };
    let part = partition(&a, Some(&sys.coords), nparts, PartitionStrategy::Contiguous);
    let a_perm = a.permute_sym(&part.perm);
    let shares = Arc::new(distribute(&a_perm, &part));
    (a_perm, part, shares)
}

#[test]
fn dist_cg_and_pipelined_match_serial_across_rank_counts() {
    let g = 16;
    for nparts in [1usize, 2, 4] {
        let (a_perm, part, shares) = dist_setup(g, nparts, 0.0);
        let mut rng = Prng::new(40 + nparts as u64);
        let b = Arc::new(rng.normal_vec(g * g));
        let x_ref = rsla::direct::direct_solve(&a_perm, &b).unwrap();
        let part = Arc::new(part);

        for pipelined in [false, true] {
            let (bc, p2, ps) = (b.clone(), part.clone(), shares.clone());
            let reports = run_ranks(nparts, move |c| {
                let p = c.rank();
                let range = p2.rank_range(p);
                let opts = DistIterOpts {
                    tol: 1e-11,
                    ..Default::default()
                };
                if pipelined {
                    dist_cg_pipelined(&ps[p], &bc[range], &c, &opts)
                } else {
                    dist_cg(&ps[p], &bc[range], &c, &opts)
                }
            });
            assert!(reports.iter().all(|r| r.converged));
            let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
            assert!(
                util::rel_l2(&x, &x_ref) < 1e-7,
                "ranks={nparts} pipelined={pipelined}"
            );
        }
    }
}

#[test]
fn dist_gmres_matches_serial_across_rank_counts() {
    let g = 12;
    for nparts in [1usize, 2, 4] {
        let (a_perm, part, shares) = dist_setup(g, nparts, 0.0);
        let mut rng = Prng::new(50 + nparts as u64);
        let b = Arc::new(rng.normal_vec(g * g));
        let x_ref = rsla::direct::direct_solve(&a_perm, &b).unwrap();
        let part = Arc::new(part);
        let (bc, p2, ps) = (b.clone(), part.clone(), shares.clone());
        let reports = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            dist_gmres(
                &ps[p],
                &bc[range],
                40,
                &c,
                &DistIterOpts {
                    tol: 1e-10,
                    ..Default::default()
                },
            )
        });
        assert!(reports.iter().all(|r| r.converged), "ranks={nparts}");
        let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(util::rel_l2(&x, &x_ref) < 1e-7, "ranks={nparts}");
    }
}

#[test]
fn dist_minres_solves_symmetric_indefinite_across_rank_counts() {
    // shifted Poisson with the shift inside the spectrum: symmetric
    // INDEFINITE — CG's assumption fails; distributed MINRES converges
    // and matches the direct solution.
    let g = 10;
    let shift = 30.0;
    for nparts in [1usize, 2, 4] {
        let (a_perm, part, shares) = dist_setup(g, nparts, shift);
        let mut rng = Prng::new(60 + nparts as u64);
        let b = Arc::new(rng.normal_vec(g * g));
        let x_ref = rsla::direct::direct_solve(&a_perm, &b).unwrap();
        let part = Arc::new(part);
        let (bc, p2, ps) = (b.clone(), part.clone(), shares.clone());
        let reports = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            dist_minres(
                &ps[p],
                &bc[range],
                &c,
                &DistIterOpts {
                    tol: 1e-10,
                    max_iters: 50_000,
                    ..Default::default()
                },
            )
        });
        assert!(reports.iter().all(|r| r.converged), "ranks={nparts}");
        let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(util::rel_l2(&x, &x_ref) < 1e-6, "ranks={nparts}");
    }
}

#[test]
fn dist_bicgstab_matches_serial_across_rank_counts() {
    let g = 12;
    for nparts in [1usize, 2, 4] {
        let (a_perm, part, shares) = dist_setup(g, nparts, 0.0);
        let mut rng = Prng::new(70 + nparts as u64);
        let b = Arc::new(rng.normal_vec(g * g));
        let x_ref = rsla::direct::direct_solve(&a_perm, &b).unwrap();
        let part = Arc::new(part);
        let (bc, p2, ps) = (b.clone(), part.clone(), shares.clone());
        let reports = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            dist_bicgstab(&ps[p], &bc[range], &c, &DistIterOpts::default())
        });
        let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
        assert!(util::rel_l2(&x, &x_ref) < 1e-6, "ranks={nparts}");
    }
}

// ------------------------------------------------------------------
// 3. s-step CA-CG parity and the communication-avoiding contract
// ------------------------------------------------------------------

#[test]
fn ca_cg_matches_standard_cg_across_rank_counts_and_block_sizes() {
    let g = 16;
    for nparts in [1usize, 2, 4] {
        let (a_perm, part, shares) = dist_setup(g, nparts, 0.0);
        let mut rng = Prng::new(100 + nparts as u64);
        let b = Arc::new(rng.normal_vec(g * g));
        let x_ref = rsla::direct::direct_solve(&a_perm, &b).unwrap();
        let part = Arc::new(part);

        let (bc, p2, ps) = (b.clone(), part.clone(), shares.clone());
        let std_reports = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            let opts = DistIterOpts {
                tol: 1e-9,
                ..Default::default()
            };
            dist_cg(&ps[p], &bc[range], &c, &opts)
        });
        assert!(std_reports.iter().all(|r| r.converged));
        let std_iters = std_reports[0].iters;
        let std_rounds = std_reports[0].reduce_rounds;

        for s in [2usize, 4, 8] {
            let (bc, p2, ps) = (b.clone(), part.clone(), shares.clone());
            let reports = run_ranks(nparts, move |c| {
                let p = c.rank();
                let range = p2.rank_range(p);
                let opts = DistIterOpts {
                    tol: 1e-9,
                    ..Default::default()
                };
                let ca = CaCgOpts {
                    s,
                    ..Default::default()
                };
                dist_cg_ca(&ps[p], &bc[range], &c, &opts, &ca)
            });
            assert!(
                reports.iter().all(|r| r.converged),
                "ranks={nparts} s={s}: CA-CG did not converge"
            );
            // convergence parity: same tolerance, same solution, and an
            // iterate count within one-ish block of standard CG (the
            // monomial basis can only overshoot to an outer-step
            // boundary plus mild finite-precision drift)
            let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
            assert!(
                util::rel_l2(&x, &x_ref) < 1e-6,
                "ranks={nparts} s={s}: CA-CG solution diverged"
            );
            let iters = reports[0].iters;
            assert!(
                iters <= std_iters + 4 * s,
                "ranks={nparts} s={s}: CA-CG needed {iters} iters vs standard {std_iters}"
            );
            // the communication-avoiding contract: the packed per-outer
            // reduction must cut rounds >= 2x vs standard CG's 2/iter
            // (true for every s >= 2, basis-setup overhead included)
            assert!(
                2 * reports[0].reduce_rounds <= std_rounds,
                "ranks={nparts} s={s}: rounds {} vs standard {std_rounds} — not a 2x cut",
                reports[0].reduce_rounds
            );
            // every rank agrees on the round count (it is a collective)
            assert!(reports
                .iter()
                .all(|r| r.reduce_rounds == reports[0].reduce_rounds));
        }
    }
}

#[test]
fn ca_cg_residual_replacement_guard_falls_back_and_still_converges() {
    // `guard_factor <= 0` is the documented test hook: the drift check
    // fires on every guarded outer step, which forces the replacement
    // path and then the persistent-drift fallback to standard CG.  The
    // solve must still converge to the right answer and the report must
    // make the fallback observable.
    let g = 16;
    let nparts = 2;
    let (a_perm, part, shares) = dist_setup(g, nparts, 0.0);
    let mut rng = Prng::new(123);
    let b = Arc::new(rng.normal_vec(g * g));
    let x_ref = rsla::direct::direct_solve(&a_perm, &b).unwrap();
    let part = Arc::new(part);
    let (bc, p2, ps) = (b.clone(), part.clone(), shares.clone());
    let reports = run_ranks(nparts, move |c| {
        let p = c.rank();
        let range = p2.rank_range(p);
        let opts = DistIterOpts {
            tol: 1e-9,
            ..Default::default()
        };
        let ca = CaCgOpts {
            s: 4,
            guard_every: 1,
            guard_factor: -1.0,
            ..Default::default()
        };
        dist_cg_ca(&ps[p], &bc[range], &c, &opts, &ca)
    });
    assert!(reports.iter().all(|r| r.converged));
    assert!(
        reports.iter().all(|r| r.method == "ca-cg+fallback"),
        "forced guard must surface as the fallback method, got {:?}",
        reports[0].method
    );
    let x: Vec<f64> = reports.iter().flat_map(|r| r.x_own.clone()).collect();
    assert!(util::rel_l2(&x, &x_ref) < 1e-6);
}

/// A `JobKind::Dist` process team with a rank injected to die must
/// surface a TYPED error from the solve — never a hang: the liveness
/// monitor reaps the team and blames the dead rank.
#[test]
fn dist_dead_rank_is_a_typed_error_not_a_hang() {
    let sys = poisson2d(12, None);
    let t = DSparseTensor::from_global(&sys.matrix, None, 4, PartitionStrategy::Contiguous)
        .expect("partition");
    let mut rng = Prng::new(7);
    let b = rng.normal_vec(144);
    let opts = DistIterOpts {
        backend: CommBackend::Proc(ProcOpts {
            fail_rank: Some(3),
            timeout_ms: 60_000,
            ..ProcOpts::for_tests(TransportKind::Shm)
        }),
        ..Default::default()
    };
    match t.solve(&b, &opts) {
        Err(rsla::Error::RankDead { rank, .. }) => assert_eq!(rank, 3),
        Err(other) => panic!("expected RankDead, got: {other}"),
        Ok(_) => panic!("a dead rank must not produce a successful solve"),
    }
}

#[test]
fn transposed_halo_spmv_adjoint_matches_global_across_rank_counts() {
    // pins the H^T (sum-at-owner) path itself: A^T x computed through
    // TransposedOp over DistOp — i.e. dist_spmv_adjoint and
    // halo_exchange_adjoint — must equal the global transpose product
    // at every rank count.
    use rsla::distributed::DistOp;
    use rsla::krylov::{LinearOperator, TransposedOp};
    let g = 11;
    for nparts in [1usize, 2, 4] {
        let (a_perm, part, shares) = dist_setup(g, nparts, 0.0);
        let n = g * g;
        let mut rng = Prng::new(90 + nparts as u64);
        let x = Arc::new(rng.normal_vec(n));
        let mut want = vec![0.0; n];
        a_perm.spmv_t(&x, &mut want);
        let part = Arc::new(part);
        let (xc, p2, ps) = (x.clone(), part.clone(), shares.clone());
        let results = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            let op = DistOp::new(&ps[p], &c, 9_000);
            let t = TransposedOp(&op);
            let mut x_ext = vec![0.0; t.n_ext()];
            x_ext[..range.len()].copy_from_slice(&xc[range.clone()]);
            let mut y = vec![0.0; range.len()];
            t.apply(&mut x_ext, &mut y);
            y
        });
        let got: Vec<f64> = results.concat();
        assert!(
            util::max_abs_diff(&got, &want) < 1e-12,
            "ranks={nparts}: transposed-halo A^T x diverged from global"
        );
    }
}

#[test]
fn dist_adjoint_matches_serial_across_rank_counts() {
    let g = 10;
    for nparts in [1usize, 2, 4] {
        let (a_perm, part, shares) = dist_setup(g, nparts, 0.0);
        let n = g * g;
        let mut rng = Prng::new(80 + nparts as u64);
        let b = Arc::new(rng.normal_vec(n));
        let gy = Arc::new(rng.normal_vec(n));
        let x_ref = rsla::direct::direct_solve(&a_perm, &b).unwrap();
        let lam_ref = rsla::direct::direct_solve(&a_perm, &gy).unwrap();
        let part = Arc::new(part);
        let (bc, gc, p2, ps) = (b.clone(), gy.clone(), part.clone(), shares.clone());
        let results = run_ranks(nparts, move |c| {
            let p = c.rank();
            let range = p2.rank_range(p);
            dist_solve_adjoint(
                &ps[p],
                &bc[range.clone()],
                &gc[range],
                &c,
                &DistIterOpts {
                    tol: 1e-12,
                    max_iters: 20_000,
                    ..Default::default()
                },
            )
        });
        let x: Vec<f64> = results.iter().flat_map(|r| r.x_own.clone()).collect();
        let lam: Vec<f64> = results.iter().flat_map(|r| r.lambda_own.clone()).collect();
        assert!(util::rel_l2(&x, &x_ref) < 1e-6, "ranks={nparts}");
        assert!(util::rel_l2(&lam, &lam_ref) < 1e-6, "ranks={nparts}");
    }
}
