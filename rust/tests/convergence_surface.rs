//! Convergence telemetry must surface END-TO-END: the iteration count
//! and final residual a Krylov kernel (or Newton loop, or rank team)
//! produced have to arrive on the `JobResult` the client waits on —
//! not stay buried in the family-specific output payload.

use std::sync::Arc;

use rsla::backend::{Dispatcher, Method, SolveOpts};
use rsla::engine::{workload::MixedWorkload, Engine, EngineConfig, JobKind, JobSpec, Ticket};
use rsla::sparse::poisson::poisson2d;
use rsla::util::Prng;

fn engine(workers: usize) -> Engine {
    Engine::start(
        Arc::new(Dispatcher::new(None)),
        EngineConfig {
            workers,
            ..Default::default()
        },
    )
}

#[test]
fn iterative_linear_jobs_report_iters_and_residual() {
    let eng = engine(2);
    let sys = poisson2d(16, None);
    let mut rng = Prng::new(3);
    let b = rng.normal_vec(256);

    // force the iterative path: Auto on a small system would go direct
    let t = eng
        .submit(JobSpec::Linear {
            matrix: sys.matrix.clone(),
            b: b.clone(),
            opts: SolveOpts {
                method: Method::Cg,
                ..Default::default()
            },
        })
        .unwrap();
    let r = t.wait();
    assert!(r.outcome.is_ok(), "cg solve failed");
    let conv = r.convergence.expect("linear job must carry convergence");
    assert!(conv.converged);
    assert!(conv.iters > 0, "cg consumed no iterations?");
    assert!(conv.residual.is_finite() && conv.residual < 1e-6);

    // the direct path reports too: zero iterations, converged
    let t = eng
        .submit(JobSpec::Linear {
            matrix: sys.matrix.clone(),
            b,
            opts: SolveOpts::default(),
        })
        .unwrap();
    let r = t.wait();
    assert!(r.outcome.is_ok());
    let conv = r.convergence.expect("direct linear job must carry convergence");
    assert!(conv.converged);
    assert!(conv.residual.is_finite());
    eng.shutdown();
}

#[test]
fn every_family_surfaces_convergence_on_its_job_result() {
    let eng = engine(2);
    let mut workload = MixedWorkload::new(&[12, 16], 7);
    workload.multi_rhs = 3;
    let mut tickets: Vec<Ticket> = Vec::new();
    for i in 0..40 {
        tickets.push(eng.submit(workload.spec(i)).unwrap());
    }
    let mut kinds_seen = std::collections::HashSet::new();
    for t in tickets {
        let r = t.wait();
        kinds_seen.insert(r.kind.idx());
        match r.kind {
            // adjoint pairs carry no iteration data by design
            JobKind::Adjoint => assert!(r.convergence.is_none()),
            // failed jobs carry None — the error already says why
            _ if r.outcome.is_err() => assert!(r.convergence.is_none()),
            kind => {
                let c = r
                    .convergence
                    .unwrap_or_else(|| panic!("{} job lost its convergence", kind.name()));
                assert!(c.residual.is_finite(), "{}: residual NaN", kind.name());
                if matches!(kind, JobKind::Nonlinear | JobKind::Dist) {
                    assert!(c.converged, "{} did not converge", kind.name());
                    assert!(c.iters > 0, "{}: zero iterations", kind.name());
                }
            }
        }
    }
    assert_eq!(kinds_seen.len(), 6, "stream missed a job family");
    eng.shutdown();
}
