//! Property tests for the blocked (supernodal/panel) numeric tier:
//! random SPD and unsymmetric matrices across amalgamation thresholds,
//! blocked-vs-column numerical parity, the refactor-vs-cold bitwise pin
//! on the blocked path, and the sub-threshold fallback pin.
//!
//! These back the factor-cache swap from scalar column kernels to dense
//! panel kernels: a warm-path caller that is handed a blocked factor
//! must see (a) the same linear operator to reassociation tolerance and
//! (b) EXACTLY the factor a cold build would have produced — the repo's
//! refactor-vs-cold determinism contract does not relax for speed.

use rsla::direct::{
    build_factor, refactor, CholSymbolic, EnvelopeCholesky, LuPanels, SnCholSymbolic, SnCholesky,
    SparseLu, SupernodalOpts, Symbolic,
};
use rsla::sparse::graphs::{random_nonsymmetric, random_spd};
use rsla::sparse::poisson::poisson2d;
use rsla::sparse::Csr;
use rsla::util::Prng;

/// (max_width, relax) grid: scalar-equivalent width-1, narrow and wide
/// panels, aggressive and conservative amalgamation.
const THRESHOLDS: [(usize, f64); 5] = [(1, 0.0), (4, 0.25), (8, 0.25), (16, 1.0), (32, 0.5)];

fn opts(max_width: usize, relax: f64) -> SupernodalOpts {
    SupernodalOpts {
        max_width,
        relax,
        ..SupernodalOpts::default()
    }
}

fn spd_matrices() -> Vec<(String, Csr)> {
    let mut out = vec![("poisson2d(13)".to_string(), poisson2d(13, None).matrix)];
    for (seed, n, per_row) in [(3u64, 60usize, 3usize), (11, 95, 4), (29, 40, 6)] {
        let mut rng = Prng::new(seed);
        out.push((
            format!("random_spd(seed={seed}, n={n})"),
            random_spd(&mut rng, n, per_row, 1.5),
        ));
    }
    out
}

fn unsym_matrices() -> Vec<(String, Csr)> {
    let mut out = Vec::new();
    for (seed, n, per_row) in [(7u64, 50usize, 3usize), (17, 80, 4), (41, 35, 5)] {
        let mut rng = Prng::new(seed);
        out.push((
            format!("random_nonsymmetric(seed={seed}, n={n})"),
            random_nonsymmetric(&mut rng, n, per_row),
        ));
    }
    out
}

fn assert_close(x: &[f64], xref: &[f64], tol: f64, ctx: &str) {
    assert_eq!(x.len(), xref.len(), "{ctx}: length mismatch");
    let scale = xref.iter().fold(1.0f64, |m, v| m.max(v.abs()));
    for (i, (xi, ri)) in x.iter().zip(xref).enumerate() {
        assert!(
            (xi - ri).abs() <= tol * scale,
            "{ctx}: entry {i}: {xi} vs {ri} (scale {scale})"
        );
    }
}

fn assert_bitwise(x: &[f64], y: &[f64], ctx: &str) {
    assert_eq!(x.len(), y.len(), "{ctx}: length mismatch");
    for (i, (a, b)) in x.iter().zip(y).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "{ctx}: entry {i}: {a} vs {b}");
    }
}

// ------------------------------------------------------------------
// SPD: blocked Cholesky vs the scalar envelope kernel
// ------------------------------------------------------------------

#[test]
fn blocked_cholesky_matches_envelope_across_thresholds() {
    for (name, a) in spd_matrices() {
        let env_sym = CholSymbolic::analyze(&a, true).expect("envelope analyze");
        let env = EnvelopeCholesky::factor_numeric(&env_sym, &a.vals).expect("envelope numeric");
        let mut rng = Prng::new(99);
        let b = rng.normal_vec(a.nrows);
        let xref = env.solve(&b);
        // the two kernels run different FP schedules; agreement is at
        // reassociation tolerance, exactness is pinned per-kernel below
        let r = a.matvec(&xref);
        let scale = b.iter().fold(1.0f64, |m, v| m.max(v.abs()));
        for (ri, bi) in r.iter().zip(&b) {
            assert!((ri - bi).abs() <= 1e-7 * scale, "{name}: envelope residual");
        }
        for &(w, relax) in &THRESHOLDS {
            let sym = SnCholSymbolic::analyze(&a, true, &opts(w, relax)).expect("sn analyze");
            if !sym.engaged() {
                continue;
            }
            let sym = std::sync::Arc::new(sym);
            let f = SnCholesky::factor_numeric(&sym, &a.vals).expect("sn numeric");
            let x = f.solve(&b).expect("sn solve");
            assert_close(&x, &xref, 1e-8, &format!("{name} w={w} relax={relax}"));
        }
    }
}

#[test]
fn blocked_cholesky_is_bitwise_deterministic_per_threshold() {
    for (name, a) in spd_matrices() {
        for &(w, relax) in &THRESHOLDS {
            let sym = SnCholSymbolic::analyze(&a, true, &opts(w, relax)).expect("analyze");
            if !sym.engaged() {
                continue;
            }
            let sym = std::sync::Arc::new(sym);
            let f1 = SnCholesky::factor_numeric(&sym, &a.vals).expect("first");
            let f2 = SnCholesky::factor_numeric(&sym, &a.vals).expect("second");
            let mut rng = Prng::new(5);
            let b = rng.normal_vec(a.nrows);
            let x1 = f1.solve(&b).expect("solve 1");
            let x2 = f2.solve(&b).expect("solve 2");
            assert_bitwise(&x1, &x2, &format!("{name} w={w} relax={relax}"));
        }
    }
}

// ------------------------------------------------------------------
// Unsymmetric: blocked LU replay vs the recorded column replay
// ------------------------------------------------------------------

#[test]
fn blocked_lu_matches_column_replay_across_thresholds() {
    for (name, a) in unsym_matrices() {
        let cap = usize::MAX;
        let (f_col, sym) = SparseLu::factor_recording(&a, cap).expect("recording factor");
        let mut rng = Prng::new(23);
        let b = rng.normal_vec(a.nrows);
        let xref = f_col.solve(&b).expect("column solve");
        let tref = f_col.solve_t(&b).expect("column solve_t");
        for &(w, relax) in &THRESHOLDS {
            let plan = LuPanels::plan(&sym, &opts(w, relax));
            if !plan.engaged() {
                continue;
            }
            let fb = SparseLu::refactor_blocked(&sym, &plan, &a, cap).expect("blocked refactor");
            let x = fb.solve(&b).expect("blocked solve");
            assert_close(&x, &xref, 1e-8, &format!("{name} w={w} relax={relax} solve"));
            let t = fb.solve_t(&b).expect("blocked solve_t");
            assert_close(&t, &tref, 1e-8, &format!("{name} w={w} relax={relax} solve_t"));
        }
    }
}

#[test]
fn blocked_lu_replay_is_bitwise_deterministic() {
    for (name, a) in unsym_matrices() {
        let cap = usize::MAX;
        let (_, sym) = SparseLu::factor_recording(&a, cap).expect("recording");
        let plan = LuPanels::plan(&sym, &SupernodalOpts::default());
        if !plan.engaged() {
            continue;
        }
        let f1 = SparseLu::refactor_blocked(&sym, &plan, &a, cap).expect("first");
        let f2 = SparseLu::refactor_blocked(&sym, &plan, &a, cap).expect("second");
        let mut rng = Prng::new(31);
        let b = rng.normal_vec(a.nrows);
        let x1 = f1.solve(&b).expect("solve 1");
        let x2 = f2.solve(&b).expect("solve 2");
        assert_bitwise(&x1, &x2, name.as_str());
    }
}

// ------------------------------------------------------------------
// Refactor-vs-cold bitwise pin through the cache API, blocked path
// ------------------------------------------------------------------

#[test]
fn cache_refactor_is_bitwise_equal_to_cold_on_blocked_cholesky() {
    for (name, a) in spd_matrices() {
        let (cold, sym) = build_factor(&a, true, u64::MAX).expect("cold build");
        if cold.method() != "cholesky+rcm+sn" {
            continue; // narrow-panel matrices are pinned by the fallback test
        }
        assert!(matches!(sym, Symbolic::SnChol(_)), "{name}: symbolic kind");
        let warm = refactor(&sym, &a, true, u64::MAX).expect("warm refactor");
        assert_eq!(warm.method(), "cholesky+rcm+sn", "{name}");
        assert_eq!(cold.fill_bytes(), warm.fill_bytes(), "{name}: fill bytes");
        let mut rng = Prng::new(77);
        let b = rng.normal_vec(a.nrows);
        let xc = cold.solve(&b).expect("cold solve");
        let xw = warm.solve(&b).expect("warm solve");
        assert_bitwise(&xc, &xw, &format!("{name}: refactor-vs-cold"));
    }
}

#[test]
fn cache_refactor_is_bitwise_equal_to_cold_on_blocked_lu() {
    for (name, a) in unsym_matrices() {
        let (cold, sym) = build_factor(&a, false, u64::MAX).expect("cold build");
        assert_eq!(cold.method(), "lu", "{name}");
        let warm = refactor(&sym, &a, false, u64::MAX).expect("warm refactor");
        assert_eq!(cold.fill_bytes(), warm.fill_bytes(), "{name}: fill bytes");
        let mut rng = Prng::new(83);
        let b = rng.normal_vec(a.nrows);
        let xc = cold.solve(&b).expect("cold solve");
        let xw = warm.solve(&b).expect("warm solve");
        assert_bitwise(&xc, &xw, &format!("{name}: refactor-vs-cold"));
        let tc = cold.solve_t(&b).expect("cold solve_t");
        let tw = warm.solve_t(&b).expect("warm solve_t");
        assert_bitwise(&tc, &tw, &format!("{name}: refactor-vs-cold transpose"));
    }
}

// ------------------------------------------------------------------
// Sub-threshold fallback pins
// ------------------------------------------------------------------

#[test]
fn sub_threshold_spd_falls_back_to_envelope_kernel() {
    // identity: width-1 supernodes everywhere, below engage_min_width
    let a = Csr::identity(24);
    let sym = SnCholSymbolic::analyze(&a, true, &SupernodalOpts::default()).expect("analyze");
    assert!(!sym.engaged(), "identity must not engage the blocked kernel");
    let (f, sym) = build_factor(&a, true, u64::MAX).expect("build");
    assert_eq!(f.method(), "cholesky+rcm", "identity takes the envelope path");
    assert!(matches!(sym, Symbolic::Chol(_)));
    // and the fallback still answers correctly + refactors bitwise
    let warm = refactor(&sym, &a, true, u64::MAX).expect("warm");
    let b: Vec<f64> = (0..24).map(|i| 1.0 + i as f64).collect();
    let xc = f.solve(&b).expect("cold solve");
    let xw = warm.solve(&b).expect("warm solve");
    assert_bitwise(&xc, &xw, "identity refactor-vs-cold");
    assert_close(&xc, &b, 1e-14, "identity solve");
}

#[test]
fn sub_threshold_unsymmetric_falls_back_to_column_kernel() {
    // diagonal with one negative entry: not SPD-like, so it takes the
    // LU tier; width-1 panels never amalgamate, so the plan disengages
    let n = 16;
    let mut a = Csr::identity(n);
    a.vals[3] = -2.0;
    let (f, sym) = build_factor(&a, false, u64::MAX).expect("build");
    assert_eq!(f.method(), "lu");
    assert!(
        matches!(sym, Symbolic::Lu(_)),
        "diagonal must keep the scalar column symbolic"
    );
    let warm = refactor(&sym, &a, false, u64::MAX).expect("warm");
    let b: Vec<f64> = (0..n).map(|i| 1.0 + i as f64).collect();
    let xc = f.solve(&b).expect("cold");
    let xw = warm.solve(&b).expect("warm");
    assert_bitwise(&xc, &xw, "diagonal LU refactor-vs-cold");
}

#[test]
fn width_one_threshold_still_agrees_with_reference() {
    // max_width = 1 forces pure-scalar panels through the blocked code
    // path (panel kernels with w = 1) — the degenerate end of the knob
    let a = poisson2d(9, None).matrix;
    let o = SupernodalOpts {
        max_width: 1,
        relax: 0.0,
        engage_min_width: 1,
    };
    let sym = SnCholSymbolic::analyze(&a, true, &o).expect("analyze");
    assert!(sym.engaged(), "engage_min_width=1 engages width-1 panels");
    assert_eq!(sym.max_panel_width(), 1);
    let sym = std::sync::Arc::new(sym);
    let f = SnCholesky::factor_numeric(&sym, &a.vals).expect("numeric");
    let env_sym = CholSymbolic::analyze(&a, true).expect("env analyze");
    let env = EnvelopeCholesky::factor_numeric(&env_sym, &a.vals).expect("env numeric");
    let mut rng = Prng::new(9);
    let b = rng.normal_vec(a.nrows);
    let x = f.solve(&b).expect("solve");
    assert_close(&x, &env.solve(&b), 1e-8, "width-1 blocked vs envelope");
}
