//! Seeded multi-worker stress for rsla-trace, mirroring
//! `tests/concurrency_stress.rs`: drive a mixed-family workload through
//! an 8-worker engine with the tracer ON and assert EXACT span
//! accounting — every submitted job must appear exactly once at each
//! lifecycle stage, the export must validate against the chrome-trace
//! schema, and all six job kinds must show up in `job.exec` spans.
//!
//! This file is its own process (one `#[test]`), so the process-global
//! tracer is exclusively ours.

use std::sync::Arc;

use rsla::backend::Dispatcher;
use rsla::engine::{workload::MixedWorkload, Engine, EngineConfig, JobKind, Ticket};
use rsla::trace::{export, names as tn, validate_chrome_trace, TraceSummary, Tracer};

const REQUESTS: usize = 160;
const WORKERS: usize = 8;

#[test]
fn traced_stress_accounts_for_every_job_exactly_once() {
    let tracer = Tracer::global();
    tracer.enable();

    let engine = Engine::start(
        Arc::new(Dispatcher::new(None)),
        EngineConfig {
            workers: WORKERS,
            ..Default::default()
        },
    );
    let mut workload = MixedWorkload::new(&[12, 16, 20], 99);
    workload.multi_rhs = 3;
    let mut tickets: Vec<Ticket> = Vec::new();
    for i in 0..REQUESTS {
        tickets.push(engine.submit(workload.spec(i)).expect("admission"));
    }
    let mut failures = 0usize;
    for t in tickets {
        if t.wait().outcome.is_err() {
            failures += 1;
        }
    }
    engine.shutdown();
    tracer.disable();
    let snap = tracer.snapshot();
    assert_eq!(snap.dropped, 0, "ring overflow dropped records");

    // --- exact lifecycle accounting -----------------------------------
    let n = REQUESTS as u64;
    let events = |name: &str| {
        snap.spans
            .iter()
            .filter(|s| s.name == name && matches!(s.phase, rsla::trace::Phase::Event))
            .count() as u64
    };
    let spans = |name: &str| {
        snap.spans
            .iter()
            .filter(|s| s.name == name && matches!(s.phase, rsla::trace::Phase::Span))
            .count() as u64
    };
    assert_eq!(events(tn::JOB_SUBMIT), n, "one submit event per job");
    assert_eq!(events(tn::JOB_SCHEDULED), n, "one scheduled event per job");
    assert_eq!(events(tn::JOB_REPLY), n, "one reply event per job");
    assert_eq!(spans(tn::JOB_EXEC), n, "one exec span per job");
    assert_eq!(spans(tn::JOB_QUEUED), n, "one queued span per job");

    // every exec span carries a job id and a kind; all six kinds ran
    let mut kinds = std::collections::BTreeSet::new();
    for s in snap.spans.iter().filter(|s| s.name == tn::JOB_EXEC) {
        assert!(!s.job_kind.is_empty(), "exec span without a job kind");
        assert!(s.t_end_ns >= s.t_start_ns, "span closed before it opened");
        kinds.insert(s.job_kind);
    }
    for k in JobKind::ALL {
        assert!(kinds.contains(k.name()), "no exec span for kind {}", k.name());
    }

    // the factor-serving path left hit/miss breadcrumbs, and iterative
    // kernels left convergence records
    let cache_events = events(tn::FACTOR_HIT_NUMERIC)
        + events(tn::FACTOR_HIT_SYMBOLIC)
        + events(tn::FACTOR_MISS);
    assert!(cache_events > 0, "no factor cache events recorded");
    assert!(!snap.convs.is_empty(), "no convergence records recorded");

    // --- exported chrome trace validates against the schema -----------
    let json = export::chrome_trace_json(&snap);
    let stats = validate_chrome_trace(&json).expect("chrome trace schema");
    assert_eq!(
        stats.events,
        snap.spans.len() + snap.convs.len(),
        "export lost records"
    );
    assert!(stats.names.contains(tn::JOB_EXEC));
    assert!(stats.names.contains(tn::JOB_SUBMIT));
    for k in JobKind::ALL {
        assert!(
            stats.kinds.contains(k.name()),
            "exported trace missing kind {}",
            k.name()
        );
    }

    // --- summary agrees with the raw snapshot -------------------------
    let sum = TraceSummary::of(&snap);
    assert_eq!(sum.span_count(tn::JOB_EXEC), n);
    assert_eq!(sum.event_count(tn::JOB_SUBMIT), n);
    assert_eq!(sum.kinds_seen().len(), 6);
    assert_eq!(sum.total_records, snap.spans.len() + snap.convs.len());

    // JSONL export: one line per record
    let lines = export::jsonl(&snap);
    assert_eq!(
        lines.lines().count(),
        snap.spans.len() + snap.convs.len(),
        "jsonl line count diverged"
    );

    assert_eq!(failures, 0, "{failures} jobs failed under tracing");
}
