//! Failure injection: every layer must fail CLOSED — typed errors, clean
//! fallbacks, no poisoned state — under singular operators, budget
//! exhaustion, missing artifacts, protocol misuse, and degenerate
//! spectra.  "OOM" rows in the paper's tables are budget violations, not
//! crashes; this suite is what makes that claim trustworthy.

use std::sync::Arc;

use rsla::backend::{Device, Dispatcher, Method, Operator, Problem, SolveOpts};
use rsla::coordinator::{ServiceConfig, SolveService};
use rsla::direct::SparseLu;
use rsla::distributed::{DSparseTensor, DistIterOpts, PartitionStrategy};
use rsla::iterative::{bicgstab, cg, Identity, IterOpts, Jacobi};
use rsla::sparse::poisson::poisson2d;
use rsla::sparse::{Coo, Csr};
use rsla::tensor::SparseTensor;
use rsla::util::Prng;
use rsla::Error;

fn singular_2x2() -> Csr {
    // rank-1 matrix: [1 1; 1 1]
    let mut coo = Coo::new(2, 2);
    coo.push(0, 0, 1.0);
    coo.push(0, 1, 1.0);
    coo.push(1, 0, 1.0);
    coo.push(1, 1, 1.0);
    coo.to_csr()
}

// ---------------------------------------------------------------------
// Direct solvers
// ---------------------------------------------------------------------

#[test]
fn lu_on_singular_matrix_is_breakdown_not_panic() {
    match SparseLu::factor(&singular_2x2()) {
        Err(Error::Breakdown { .. }) => {}
        Err(e) => panic!("wrong error kind: {e}"),
        Ok(_) => panic!("factored a singular matrix"),
    }
}

#[test]
fn lu_on_structurally_deficient_matrix_errors() {
    // an empty row can never be eliminated
    let mut coo = Coo::new(3, 3);
    coo.push(0, 0, 2.0);
    coo.push(2, 2, 2.0);
    // row 1 is empty
    let a = coo.to_csr();
    assert!(SparseLu::factor(&a).is_err());
}

#[test]
fn direct_solve_rejects_shape_mismatch() {
    let sys = poisson2d(4, None);
    let f = SparseLu::factor(&sys.matrix).unwrap();
    assert!(f.solve(&vec![1.0; 3]).is_err());
}

// ---------------------------------------------------------------------
// Iterative solvers: breakdowns and budgets
// ---------------------------------------------------------------------

#[test]
fn cg_on_indefinite_matrix_stops_cleanly() {
    // CG requires SPD; on an indefinite matrix it must detect pAp <= 0
    // and stop with converged = false, never NaN-poison the iterate.
    let mut coo = Coo::new(2, 2);
    coo.push(0, 0, 1.0);
    coo.push(1, 1, -1.0);
    let a = coo.to_csr();
    let r = cg(
        &a,
        &[1.0, 1.0],
        &Identity,
        &IterOpts::default(),
        None,
    );
    assert!(!r.converged);
    assert!(r.x.iter().all(|v| v.is_finite()));
}

#[test]
fn bicgstab_breakdown_reports_unconverged_finite() {
    let r = bicgstab(
        &singular_2x2(),
        &[1.0, -1.0], // not in the range of the rank-1 operator
        &Identity,
        &IterOpts {
            tol: 1e-12,
            max_iters: 100,
            record_history: false,
        },
        None,
    );
    assert!(!r.converged);
    assert!(r.x.iter().all(|v| v.is_finite()));
}

#[test]
fn iter_budget_exhaustion_is_reported_not_hidden() {
    let sys = poisson2d(32, None);
    let r = cg(
        &sys.matrix,
        &vec![1.0; 1024],
        &Identity,
        &IterOpts {
            tol: 1e-14,
            max_iters: 5,
            record_history: false,
        },
        None,
    );
    assert!(!r.converged);
    assert_eq!(r.iters, 5);
    assert!(r.require_converged(1e-14).is_err());
}

#[test]
fn jacobi_precond_rejects_zero_diagonal() {
    let mut coo = Coo::new(2, 2);
    coo.push(0, 1, 1.0);
    coo.push(1, 0, 1.0);
    assert!(Jacobi::new(&coo.to_csr()).is_err());
}

// ---------------------------------------------------------------------
// Dispatcher: budget OOM -> typed error -> fallback chain
// ---------------------------------------------------------------------

#[test]
fn forced_backend_oom_reports_reason() {
    let sys = poisson2d(64, None);
    let b = vec![1.0; 64 * 64];
    let d = Dispatcher::new(None);
    let p = Problem {
        op: Operator::Csr(&sys.matrix),
        b: &b,
    };
    let err = d
        .solve(
            &p,
            &SolveOpts {
                backend: Some("native-direct".into()),
                host_mem_budget: 1 << 10,
                ..Default::default()
            },
        )
        .unwrap_err();
    let msg = err.to_string().to_lowercase();
    assert!(
        msg.contains("budget") || msg.contains("memory"),
        "uninformative OOM error: {msg}"
    );
}

#[test]
fn dispatch_falls_back_when_preferred_backend_oom() {
    let sys = poisson2d(64, None);
    let b = vec![1.0; 64 * 64];
    let d = Dispatcher::new(None);
    let p = Problem {
        op: Operator::Csr(&sys.matrix),
        b: &b,
    };
    let out = d
        .solve(
            &p,
            &SolveOpts {
                host_mem_budget: 1 << 10, // direct cannot fit
                ..Default::default()
            },
        )
        .expect("dispatcher must fall back to iterative");
    assert_eq!(out.backend, "native-iter");
}

#[test]
fn unknown_backend_name_is_a_clean_error() {
    let sys = poisson2d(8, None);
    let b = vec![1.0; 64];
    let d = Dispatcher::new(None);
    let p = Problem {
        op: Operator::Csr(&sys.matrix),
        b: &b,
    };
    assert!(d
        .solve(
            &p,
            &SolveOpts {
                backend: Some("petsc".into()), // not registered (yet)
                ..Default::default()
            },
        )
        .is_err());
}

#[test]
fn method_override_incompatible_with_backend_errors() {
    // asking the direct backend for CG must refuse, not silently ignore
    let sys = poisson2d(8, None);
    let b = vec![1.0; 64];
    let d = Dispatcher::new(None);
    let p = Problem {
        op: Operator::Csr(&sys.matrix),
        b: &b,
    };
    let r = d.solve(
        &p,
        &SolveOpts {
            backend: Some("native-direct".into()),
            method: Method::Cg,
            ..Default::default()
        },
    );
    assert!(r.is_err(), "direct backend accepted method=cg");
}

// ---------------------------------------------------------------------
// Runtime: missing artifacts directory / missing artifact name
// ---------------------------------------------------------------------

#[test]
fn runtime_on_missing_dir_errors_without_panicking() {
    assert!(rsla::runtime::RuntimeHandle::spawn("/nonexistent/path/artifacts").is_err());
}

#[test]
fn accel_dispatch_without_artifacts_falls_back_to_native() {
    // a dispatcher with NO runtime must still serve Accel requests via
    // the native fallback rather than erroring
    let sys = poisson2d(16, None);
    let b = vec![1.0; 256];
    let d = Dispatcher::new(None);
    let p = Problem {
        op: Operator::Csr(&sys.matrix),
        b: &b,
    };
    let out = d.solve(&p, &SolveOpts::on_accel()).unwrap();
    assert!(out.backend.starts_with("native"));
}

// ---------------------------------------------------------------------
// Typed tensors: shape and batch misuse
// ---------------------------------------------------------------------

#[test]
fn sparse_tensor_batched_rejects_wrong_value_length() {
    let sys = poisson2d(4, None);
    let pat = rsla::sparse::Pattern::of(&sys.matrix);
    let bad = vec![vec![1.0; pat.nnz() - 1]];
    assert!(SparseTensor::batched(pat, bad).is_err());
}

#[test]
fn solve_batch_rejects_mismatched_rhs_count() {
    // (a batch of ONE with many rhs is the documented multi-rhs path,
    // so the mismatch check needs a genuine batch)
    let sys = poisson2d(4, None);
    let pat = rsla::sparse::Pattern::of(&sys.matrix);
    let a = SparseTensor::batched(
        pat,
        vec![sys.matrix.vals.clone(), sys.matrix.vals.clone()],
    )
    .unwrap();
    let bs = vec![vec![1.0; 16]; 3]; // 3 rhs for a batch of 2
    assert!(a.solve_batch(&bs, &SolveOpts::default()).is_err());
}

#[test]
fn eigsh_on_nonsymmetric_tensor_errors() {
    let mut coo = Coo::new(4, 4);
    for i in 0..4 {
        coo.push(i, i, 2.0);
    }
    coo.push(0, 1, 1.0);
    let a = SparseTensor::from_csr(coo.to_csr());
    assert!(a
        .eigsh(1, &rsla::eigen::LobpcgOpts::default())
        .is_err());
}

// ---------------------------------------------------------------------
// Distributed: bad partition counts, non-SPD adjoint, shape mismatch
// ---------------------------------------------------------------------

#[test]
fn from_global_rejects_bad_partition_counts() {
    let sys = poisson2d(4, None);
    assert!(
        DSparseTensor::from_global(&sys.matrix, None, 0, PartitionStrategy::Contiguous).is_err()
    );
    assert!(DSparseTensor::from_global(
        &sys.matrix,
        None,
        16, // == nrows: legal (one row per rank)
        PartitionStrategy::Contiguous
    )
    .is_ok());
    assert!(DSparseTensor::from_global(
        &sys.matrix,
        None,
        17, // > nrows
        PartitionStrategy::Contiguous
    )
    .is_err());
}

#[test]
fn distributed_adjoint_requires_spd() {
    use rsla::sparse::graphs::random_nonsymmetric;
    let mut rng = Prng::new(0);
    let a = random_nonsymmetric(&mut rng, 24, 3);
    let d = DSparseTensor::from_global(&a, None, 2, PartitionStrategy::Contiguous).unwrap();
    let b = vec![1.0; 24];
    let g = vec![1.0; 24];
    assert!(d.solve_adjoint(&b, &g, &DistIterOpts::default()).is_err());
}

#[test]
fn rcb_without_coords_degrades_gracefully() {
    // requesting RCB with no coordinates must still produce a valid
    // partition (falls back to a coordinate-free strategy), not panic
    let sys = poisson2d(8, None);
    let d = DSparseTensor::from_global(&sys.matrix, None, 2, PartitionStrategy::Rcb);
    match d {
        Ok(t) => {
            // partition must cover all rows exactly once
            assert_eq!(t.nrows(), 64);
            let b = vec![1.0; 64];
            let (x, _) = t.solve(&b, &DistIterOpts::default()).unwrap();
            assert_eq!(x.len(), 64);
        }
        Err(e) => {
            let msg = e.to_string().to_lowercase();
            assert!(msg.contains("coord"), "unhelpful error: {msg}");
        }
    }
}

// ---------------------------------------------------------------------
// Coordinator service under hostile load
// ---------------------------------------------------------------------

#[test]
fn service_returns_errors_for_unsolvable_requests_and_survives() {
    let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
    // interleave good and bad (singular) requests
    let sys = poisson2d(8, None);
    let mut rxs = Vec::new();
    for i in 0..10 {
        if i % 2 == 0 {
            rxs.push((true, svc.submit(sys.matrix.clone(), vec![1.0; 64], SolveOpts::default())));
        } else {
            rxs.push((
                false,
                svc.submit(singular_2x2(), vec![1.0, -1.0], SolveOpts::default()),
            ));
        }
    }
    for (ok, rx) in rxs {
        let resp = rx.recv().expect("service must reply to every request");
        assert_eq!(resp.outcome.is_ok(), ok, "request class mishandled");
    }
    // the service must still work after serving failures
    let rx = svc.submit(sys.matrix.clone(), vec![1.0; 64], SolveOpts::default());
    assert!(rx.recv().unwrap().outcome.is_ok());
    svc.shutdown();
}

#[test]
fn service_shutdown_drains_inflight_requests() {
    let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
    let sys = poisson2d(24, None);
    let rxs: Vec<_> = (0..16)
        .map(|_| svc.submit(sys.matrix.clone(), vec![1.0; 576], SolveOpts::default()))
        .collect();
    svc.shutdown(); // must not drop queued work
    for rx in rxs {
        assert!(rx.recv().expect("request dropped at shutdown").outcome.is_ok());
    }
}

#[test]
fn service_submit_after_shutdown_is_an_error_reply_not_a_panic() {
    let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
    svc.shutdown();
    let sys = poisson2d(6, None);
    // the old shim panicked the SUBMITTING thread here; a stopped
    // engine must instead surface as an error reply on the channel
    let rx = svc.submit(sys.matrix.clone(), vec![1.0; 36], SolveOpts::default());
    let resp = rx.recv().expect("stopped service must still reply");
    assert!(
        resp.outcome.is_err(),
        "submit to a stopped engine cannot succeed"
    );
}

// ---------------------------------------------------------------------
// Autograd tape misuse
// ---------------------------------------------------------------------

#[test]
fn backward_of_constant_yields_no_gradient_for_unrelated_leaf() {
    use rsla::autograd::Tape;
    let tape = Tape::new();
    let a = tape.leaf_vec(vec![1.0, 2.0]);
    let b = tape.leaf_vec(vec![3.0, 4.0]);
    let loss = tape.dot(a, a);
    let grads = tape.backward(loss);
    assert!(grads.get(b).is_none(), "unrelated leaf got a gradient");
}

#[test]
fn nan_in_rhs_propagates_to_unconverged_not_hang() {
    let sys = poisson2d(8, None);
    let mut b = vec![1.0; 64];
    b[0] = f64::NAN;
    let r = cg(
        &sys.matrix,
        &b,
        &Identity,
        &IterOpts {
            tol: 1e-10,
            max_iters: 1000,
            record_history: false,
        },
        None,
    );
    assert!(!r.converged, "NaN rhs cannot converge");
}

// ---------------------------------------------------------------------
// Solve engine failure paths: every failure is a typed JobResult error,
// never a hang, and the worker pool survives its workers' worst day
// ---------------------------------------------------------------------

mod engine_failures {
    use super::*;
    use rsla::engine::{Engine, EngineConfig, JobOutput, JobSpec, SubmitOpts};
    use rsla::nonlinear::{NewtonOpts, Residual};

    fn engine(workers: usize, max_pending: usize) -> Engine {
        Engine::start(
            Arc::new(Dispatcher::new(None)),
            EngineConfig {
                workers,
                max_pending,
                ..Default::default()
            },
        )
    }

    /// A residual that panics on first evaluation — the hostile-user
    /// payload an engine worker must survive.
    struct PanickingResidual;

    impl Residual for PanickingResidual {
        fn dim(&self) -> usize {
            4
        }

        fn eval(&self, _u: &[f64], _out: &mut [f64]) {
            panic!("user residual exploded");
        }

        fn jacobian(&self, _u: &[f64]) -> Csr {
            unreachable!("eval panics first")
        }
    }

    /// A residual that sleeps, to hold a worker busy deterministically.
    struct SlowResidual {
        ms: u64,
    }

    impl Residual for SlowResidual {
        fn dim(&self) -> usize {
            2
        }

        fn eval(&self, _u: &[f64], out: &mut [f64]) {
            std::thread::sleep(std::time::Duration::from_millis(self.ms));
            out.fill(0.0); // converged immediately after the nap
        }

        fn jacobian(&self, _u: &[f64]) -> Csr {
            let mut coo = Coo::new(2, 2);
            coo.push(0, 0, 1.0);
            coo.push(1, 1, 1.0);
            coo.to_csr()
        }
    }

    #[test]
    fn worker_panic_is_a_job_error_not_a_hang_and_the_pool_survives() {
        let e = engine(1, usize::MAX);
        let r = e
            .submit(JobSpec::Nonlinear {
                residual: Box::new(PanickingResidual),
                u0: vec![0.0; 4],
                opts: NewtonOpts::default(),
            })
            .unwrap()
            .wait();
        match r.outcome {
            Err(Error::WorkerPanic(msg)) => {
                assert!(msg.contains("user residual exploded"), "lost panic payload: {msg}")
            }
            Err(e) => panic!("expected WorkerPanic, got {e}"),
            Ok(_) => panic!("panicking job reported success"),
        }
        // the SAME worker (workers = 1) must still serve new jobs
        let sys = poisson2d(6, None);
        let r = e
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: vec![1.0; 36],
                opts: SolveOpts::default(),
            })
            .unwrap()
            .wait();
        assert!(r.outcome.is_ok(), "worker pool did not survive the panic");
        assert_eq!(e.stats().queue_depth, 0);
        e.shutdown();
    }

    #[test]
    fn worker_panic_leaves_the_metrics_registry_usable() {
        use rsla::metrics::names;
        let e = engine(1, usize::MAX);
        let r = e
            .submit(JobSpec::Nonlinear {
                residual: Box::new(PanickingResidual),
                u0: vec![0.0; 4],
                opts: NewtonOpts::default(),
            })
            .unwrap()
            .wait();
        assert!(r.outcome.is_err(), "panicking job reported success");
        // the unwind crossed registry lock scopes; poison recovery must
        // keep every counter and the stats snapshot fully readable
        assert_eq!(e.metrics.get(names::ENGINE_PANIC), 1, "panic not counted");
        e.metrics.incr(names::ENGINE_PANIC, 1);
        assert_eq!(e.metrics.get(names::ENGINE_PANIC), 2, "counter unusable after panic");
        assert_eq!(e.stats().queue_depth, 0);
        e.shutdown();
    }

    #[test]
    fn expired_deadline_surfaces_timeout_without_executing() {
        let e = engine(1, usize::MAX);
        let sys = poisson2d(6, None);
        // a zero budget-to-start can never be met, even by an idle
        // worker: the job must fail with Timeout, not run
        let r = e
            .submit_with(
                JobSpec::Linear {
                    matrix: sys.matrix.clone(),
                    b: vec![1.0; 36],
                    opts: SolveOpts::default(),
                },
                SubmitOpts {
                    deadline: Some(std::time::Duration::ZERO),
                    ..Default::default()
                },
            )
            .unwrap()
            .wait();
        match r.outcome {
            Err(Error::Timeout { .. }) => {}
            Err(e) => panic!("expected Timeout, got {e}"),
            Ok(_) => panic!("zero-deadline job executed"),
        }
        assert_eq!(r.worker, usize::MAX, "timed-out job must never reach a worker");
        assert!(e.stats().timeouts >= 1);
        // a sane deadline on the now-idle engine still succeeds
        let r = e
            .submit_with(
                JobSpec::Linear {
                    matrix: sys.matrix.clone(),
                    b: vec![1.0; 36],
                    opts: SolveOpts::default(),
                },
                SubmitOpts {
                    deadline: Some(std::time::Duration::from_secs(30)),
                    ..Default::default()
                },
            )
            .unwrap()
            .wait();
        assert!(r.outcome.is_ok());
        e.shutdown();
    }

    #[test]
    fn deadline_lapsing_in_queue_behind_a_slow_job_times_out() {
        let e = engine(1, usize::MAX);
        // occupy the only worker for ~400ms
        let slow = e
            .submit(JobSpec::Nonlinear {
                residual: Box::new(SlowResidual { ms: 400 }),
                u0: vec![0.0; 2],
                opts: NewtonOpts::default(),
            })
            .unwrap();
        // let the scheduler hand the slow job to the worker first
        std::thread::sleep(std::time::Duration::from_millis(100));
        let sys = poisson2d(6, None);
        let queued = e
            .submit_with(
                JobSpec::Linear {
                    matrix: sys.matrix.clone(),
                    b: vec![1.0; 36],
                    opts: SolveOpts::default(),
                },
                SubmitOpts {
                    deadline: Some(std::time::Duration::from_millis(10)),
                    ..Default::default()
                },
            )
            .unwrap();
        let r = queued.wait();
        match r.outcome {
            Err(Error::Timeout {
                waited_ms,
                deadline_ms,
            }) => assert!(
                waited_ms >= deadline_ms,
                "timeout reported a wait ({waited_ms}ms) shorter than the deadline ({deadline_ms}ms)"
            ),
            Err(e) => panic!("expected Timeout for the queued job, got {e}"),
            Ok(_) => panic!("expired queued job executed anyway"),
        }
        assert!(slow.wait().outcome.is_ok(), "slow job must still complete");
        e.shutdown();
    }

    #[test]
    fn queue_full_admission_rejection_sheds_load_without_losing_accepted_work() {
        let e = engine(1, 1);
        let slow = e
            .submit(JobSpec::Nonlinear {
                residual: Box::new(SlowResidual { ms: 300 }),
                u0: vec![0.0; 2],
                opts: NewtonOpts::default(),
            })
            .unwrap();
        // pending == max_pending: the next submit must bounce
        let sys = poisson2d(6, None);
        let err = e
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: vec![1.0; 36],
                opts: SolveOpts::default(),
            })
            .unwrap_err();
        match err {
            Error::QueueFull { depth, capacity } => {
                assert!(depth >= capacity, "rejected below capacity: {depth}/{capacity}")
            }
            other => panic!("expected QueueFull, got {other:?}"),
        }
        assert!(e.stats().rejected >= 1);
        // the accepted job is unaffected by the shed load
        assert!(slow.wait().outcome.is_ok());
        // capacity freed: admission works again
        let r = e
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: vec![1.0; 36],
                opts: SolveOpts::default(),
            })
            .unwrap()
            .wait();
        assert!(r.outcome.is_ok());
        e.shutdown();
    }

    #[test]
    fn engine_shutdown_drains_inflight_jobs() {
        let e = engine(2, usize::MAX);
        let sys = poisson2d(16, None);
        let tickets: Vec<_> = (0..12)
            .map(|_| {
                e.submit(JobSpec::Linear {
                    matrix: sys.matrix.clone(),
                    b: vec![1.0; 256],
                    opts: SolveOpts::default(),
                })
                .unwrap()
            })
            .collect();
        e.shutdown(); // must not drop queued work
        for t in tickets {
            assert!(
                t.wait().outcome.is_ok(),
                "job dropped at engine shutdown"
            );
        }
        // every JobOutput variant still pattern-matches after shutdown
        // (compile-time exhaustiveness guard for the enum)
        fn _exhaustive(out: JobOutput) {
            match out {
                JobOutput::Linear(_)
                | JobOutput::MultiRhs(_)
                | JobOutput::Nonlinear(_)
                | JobOutput::Eig(_)
                | JobOutput::Adjoint { .. }
                | JobOutput::Dist { .. } => {}
            }
        }
    }
}
