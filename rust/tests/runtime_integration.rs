//! Integration: AOT artifacts (L1 Pallas + L2 JAX, compiled to HLO by
//! `make artifacts`) executed through the PJRT runtime must agree with
//! the native Rust substrate — the cross-language contract of the
//! three-layer architecture.

use rsla::runtime::{Arg, Registry};
use rsla::sparse::graphs::{bounded_degree_laplacian, to_ell};
use rsla::sparse::poisson::{kappa_star, poisson2d, stencil_coeffs};
use rsla::util::{self, Prng};

/// Returns None (and the tests below skip) when the AOT artifacts or
/// the real PJRT bindings are unavailable in this build — the offline
/// container vendors a stub `xla` crate, so these integration tests
/// only run where `make artifacts` has been executed against real
/// bindings.
fn registry() -> Option<Registry> {
    match Registry::open_default() {
        Ok(r) => Some(r),
        Err(e) => {
            eprintln!("skipping runtime integration test: {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_families() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    for name in [
        "stencil_spmv_g32",
        "stencil_residual_g64",
        "stencil_grad_g64",
        "cg_poisson_g64",
        "dense_solve_n64",
        "ell_spmv_n4096_s8",
        "cg_ell_n4096_s8",
        "dot_n65536",
    ] {
        assert!(reg.has(name), "missing artifact {name}");
    }
}

#[test]
fn stencil_spmv_artifact_matches_native_csr() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    let g = 32;
    let kappa = kappa_star(g);
    let sys = poisson2d(g, Some(&kappa));
    let mut rng = Prng::new(0);
    let x = rng.normal_vec(g * g);

    let out = reg
        .run(
            "stencil_spmv_g32",
            &[
                Arg::tensor(sys.coeffs.to_planes(), vec![5, g, g]),
                Arg::tensor(x.clone(), vec![g, g]),
            ],
        )
        .unwrap();
    let y_xla = out[0].as_f64();
    let y_native = sys.matrix.matvec(&x);
    assert!(
        util::max_abs_diff(y_xla, &y_native) < 1e-9,
        "kernel vs CSR mismatch: {}",
        util::max_abs_diff(y_xla, &y_native)
    );
}

#[test]
fn fused_cg_artifact_solves_poisson() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    let g = 32;
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let mut rng = Prng::new(1);
    let b = rng.normal_vec(g * g);

    let out = reg
        .run(
            "cg_poisson_g32",
            &[
                Arg::tensor(sys.coeffs.to_planes(), vec![5, g, g]),
                Arg::tensor(b.clone(), vec![g, g]),
                Arg::ScalarI32(10_000),
                Arg::ScalarF64(1e-10),
            ],
        )
        .unwrap();
    let x = out[0].as_f64();
    let rr = out[1].scalar_f64();
    let iters = out[2].scalar_i32();
    assert!(rr.sqrt() <= 1e-10, "residual {}", rr.sqrt());
    assert!(iters > 10 && iters < 10_000);
    assert!(util::rel_l2(&sys.matrix.matvec(x), &b) < 1e-8);
}

#[test]
fn fused_cg_respects_iteration_budget() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    let g = 32;
    let coeffs = stencil_coeffs(g, None);
    let out = reg
        .run(
            "cg_poisson_g32",
            &[
                Arg::tensor(coeffs.to_planes(), vec![5, g, g]),
                Arg::tensor(vec![1.0; g * g], vec![g, g]),
                Arg::ScalarI32(7),
                Arg::ScalarF64(0.0),
            ],
        )
        .unwrap();
    assert_eq!(out[2].scalar_i32(), 7);
}

#[test]
fn dense_solve_artifact_spd() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    let n = 64;
    let mut rng = Prng::new(2);
    // SPD dense matrix: B B^T + n I
    let b_m: Vec<f64> = rng.normal_vec(n * n);
    let mut a = vec![0f64; n * n];
    for i in 0..n {
        for j in 0..n {
            let mut s = 0.0;
            for k in 0..n {
                s += b_m[i * n + k] * b_m[j * n + k];
            }
            a[i * n + j] = s + if i == j { n as f64 } else { 0.0 };
        }
    }
    let rhs = rng.normal_vec(n);
    let out = reg
        .run(
            "dense_solve_n64",
            &[Arg::tensor(a.clone(), vec![n, n]), Arg::vec(rhs.clone())],
        )
        .unwrap();
    let x = out[0].as_f64();
    // check A x = b
    let mut ax = vec![0f64; n];
    for i in 0..n {
        for j in 0..n {
            ax[i] += a[i * n + j] * x[j];
        }
    }
    assert!(util::rel_l2(&ax, &rhs) < 1e-9);
}

#[test]
fn ell_spmv_artifact_matches_native() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    let n = 4096;
    let s = 8;
    let mut rng = Prng::new(3);
    let a = bounded_degree_laplacian(&mut rng, n, 7, 0.3);
    let (cols, vals) = to_ell(&a, s).expect("degree fits slots");
    let x = rng.normal_vec(n);
    let out = reg
        .run(
            "ell_spmv_n4096_s8",
            &[
                Arg::I32(std::sync::Arc::new(cols), vec![n, s]),
                Arg::tensor(vals, vec![n, s]),
                Arg::vec(x.clone()),
            ],
        )
        .unwrap();
    let y = out[0].as_f64();
    let y_native = a.matvec(&x);
    assert!(util::max_abs_diff(y, &y_native) < 1e-10);
}

#[test]
fn cg_ell_artifact_solves_laplacian() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    let n = 4096;
    let s = 8;
    let mut rng = Prng::new(4);
    let a = bounded_degree_laplacian(&mut rng, n, 7, 0.5);
    let (cols, vals) = to_ell(&a, s).unwrap();
    let b = rng.normal_vec(n);
    let diag = a.diag();
    let out = reg
        .run(
            "cg_ell_n4096_s8",
            &[
                Arg::I32(std::sync::Arc::new(cols), vec![n, s]),
                Arg::tensor(vals, vec![n, s]),
                Arg::vec(diag),
                Arg::vec(b.clone()),
                Arg::ScalarI32(5000),
                Arg::ScalarF64(1e-9),
            ],
        )
        .unwrap();
    let x = out[0].as_f64();
    assert!(util::rel_l2(&a.matvec(x), &b) < 1e-7);
}

#[test]
fn stencil_grad_artifact_matches_adjoint_formula() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    let g = 32;
    let mut rng = Prng::new(5);
    let lam = rng.normal_vec(g * g);
    let x = rng.normal_vec(g * g);
    let out = reg
        .run(
            "stencil_grad_g32",
            &[
                Arg::tensor(lam.clone(), vec![g, g]),
                Arg::tensor(x.clone(), vec![g, g]),
            ],
        )
        .unwrap();
    let grad = out[0].as_f64(); // (5, g, g)
    // native formula: dcenter = -lam * x etc (shifted reads)
    let at = |v: &[f64], i: isize, j: isize| -> f64 {
        if i < 0 || j < 0 || i >= g as isize || j >= g as isize {
            0.0
        } else {
            v[(i as usize) * g + j as usize]
        }
    };
    let n = g * g;
    for i in 0..g as isize {
        for j in 0..g as isize {
            let k = (i as usize) * g + j as usize;
            let l = lam[k];
            assert!((grad[k] + l * at(&x, i, j)).abs() < 1e-11); // center
            assert!((grad[n + k] + l * at(&x, i - 1, j)).abs() < 1e-11); // up
            assert!((grad[2 * n + k] + l * at(&x, i + 1, j)).abs() < 1e-11); // dn
            assert!((grad[3 * n + k] + l * at(&x, i, j - 1)).abs() < 1e-11); // lf
            assert!((grad[4 * n + k] + l * at(&x, i, j + 1)).abs() < 1e-11); // rt
        }
    }
}

#[test]
fn executable_cache_compiles_once() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    let e1 = reg.executable("dot_n65536").unwrap();
    let t_after_first = reg.compile_seconds();
    let e2 = reg.executable("dot_n65536").unwrap();
    assert!(std::sync::Arc::ptr_eq(&e1, &e2));
    assert_eq!(reg.compile_seconds(), t_after_first);
}

#[test]
fn arity_and_shape_validation() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    // wrong arg count
    assert!(reg.run("dot_n65536", &[Arg::vec(vec![0.0; 65536])]).is_err());
    // wrong element count
    assert!(reg
        .run(
            "dot_n65536",
            &[Arg::vec(vec![0.0; 10]), Arg::vec(vec![0.0; 65536])]
        )
        .is_err());
    // unknown artifact
    assert!(reg.run("nope", &[]).is_err());
}

#[test]
fn dot_artifact_matches_native() {
    let reg = match registry() {
        Some(r) => r,
        None => return,
    };
    let mut rng = Prng::new(6);
    let x = rng.normal_vec(65536);
    let y = rng.normal_vec(65536);
    let out = reg
        .run("dot_n65536", &[Arg::vec(x.clone()), Arg::vec(y.clone())])
        .unwrap();
    let want = util::dot(&x, &y);
    assert!((out[0].scalar_f64() - want).abs() < 1e-6 * want.abs().max(1.0));
}
