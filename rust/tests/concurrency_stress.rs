//! Seeded multi-thread interleaving stress for the lock-holding layers
//! the concurrency audit (TSan/Miri in CI) watches: `CacheShards` and
//! the metrics registry/histogram.
//!
//! The schedule is nondeterministic but the *counters* are not: after a
//! warm phase that replicates every pattern onto every shard with an
//! unbounded budget, each of the `THREADS x ITERS` stress operations is
//! exactly one shard-local numeric hit, one histogram sample, and one
//! counter increment — so every final counter has one correct value,
//! and any lost update, double count, or poisoned lock fails the
//! assertion instead of flaking.

use std::sync::Arc;
use std::thread;

use rsla::factor_cache::CacheShards;
use rsla::metrics::{names, LatencyHist, Registry};
use rsla::sparse::poisson::poisson2d;
use rsla::sparse::PatternKey;
use rsla::util::Prng;

const SHARDS: usize = 4;
const THREADS: usize = 8;
const ITERS: usize = 64;

#[test]
fn seeded_shard_and_hist_stress_has_exact_final_counters() {
    let shards = Arc::new(CacheShards::new(SHARDS, u64::MAX));
    let reg = Arc::new(Registry::new());
    let hist = Arc::new(LatencyHist::new());
    let mats: Vec<_> = [5usize, 6, 7]
        .iter()
        .map(|&g| poisson2d(g, None).matrix)
        .collect();
    let keys: Vec<_> = mats.iter().map(PatternKey::of).collect();

    // Warm phase: every pattern factored onto every shard, so the
    // stress phase below performs no numeric work and no eviction.
    for i in 0..SHARDS {
        for (m, k) in mats.iter().zip(&keys) {
            shards
                .factor_on_keyed(i, m, k, u64::MAX, Some(&reg))
                .expect("warm factorization");
        }
    }
    let warm_factored = reg.get(names::FACTOR_CACHE_NUMERIC_FACTORIZATIONS);
    assert_eq!(warm_factored, (SHARDS * mats.len()) as u64);
    let warm_hits = reg.get(names::FACTOR_CACHE_HIT_NUMERIC);
    let warm_local = reg.get(names::FACTOR_CACHE_SHARD_LOCAL_HIT);
    let warm_cross = reg.get(names::FACTOR_CACHE_CROSS_SHARD_MISS);
    let warm_miss = reg.get(names::FACTOR_CACHE_MISS);

    let handles: Vec<_> = (0..THREADS)
        .map(|t| {
            let (shards, reg, hist) = (shards.clone(), reg.clone(), hist.clone());
            let (mats, keys) = (mats.clone(), keys.clone());
            thread::spawn(move || {
                let mut rng = Prng::new(0xD00D + t as u64);
                let mut scratch = Vec::new();
                for _ in 0..ITERS {
                    let which = rng.below(mats.len());
                    let shard = rng.below(SHARDS);
                    let t0 = std::time::Instant::now();
                    let f = shards
                        .factor_on_keyed(shard, &mats[which], &keys[which], u64::MAX, Some(&reg))
                        .expect("stress factorization");
                    let n = mats[which].nrows;
                    let b = vec![1.0; n];
                    let mut x = vec![0.0; n];
                    f.solve_into(&b, &mut x, &mut scratch)
                        .expect("stress solve");
                    hist.record(t0.elapsed().as_secs_f64());
                    reg.incr(names::ENGINE_COMPLETED, 1);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("stress thread panicked");
    }

    let total = (THREADS * ITERS) as u64;
    assert_eq!(
        reg.get(names::FACTOR_CACHE_NUMERIC_FACTORIZATIONS),
        warm_factored,
        "stress phase must not refactor"
    );
    assert_eq!(reg.get(names::FACTOR_CACHE_MISS), warm_miss);
    assert_eq!(
        reg.get(names::FACTOR_CACHE_HIT_NUMERIC) - warm_hits,
        total,
        "every stress op must be a numeric hit"
    );
    assert_eq!(
        reg.get(names::FACTOR_CACHE_SHARD_LOCAL_HIT) - warm_local,
        total,
        "every stress op must hit its routed shard"
    );
    assert_eq!(
        reg.get(names::FACTOR_CACHE_CROSS_SHARD_MISS),
        warm_cross,
        "no cross-shard miss once every shard is warm"
    );
    assert_eq!(reg.get(names::ENGINE_COMPLETED), total);
    assert_eq!(hist.count(), total, "histogram lost or duplicated samples");
    // quantiles stay readable (non-NaN) after concurrent recording
    assert!(hist.quantile(0.5).is_finite());
}
