//! End-to-end: the full three-layer stack on a real small workload.
//!
//! These tests prove the layers compose: 2D Poisson assembly (substrate)
//! → auto-dispatched solves across native AND xla/PJRT backends → O(1)
//! adjoint gradients through the solve → nonlinear + eigenvalue adjoints
//! → distributed domain decomposition with transposed-halo backward →
//! coordinator service batching → the paper's Fig. 3 inverse
//! coefficient-learning loop (compressed) recovering kappa from
//! observations alone.

use std::sync::Arc;

use rsla::autograd::Tape;
use rsla::backend::{Device, Method, SolveOpts};
use rsla::coordinator::{ServiceConfig, SolveService};
use rsla::distributed::{DSparseTensor, DistIterOpts, PartitionStrategy};
use rsla::eigen::LobpcgOpts;
use rsla::nonlinear::NewtonOpts;
use rsla::optim::Adam;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::sparse::{Csr, Pattern};
use rsla::tensor::{PoissonAssembler, SparseTensor, SparseTensorList};
use rsla::util::{self, dot, norm2, rel_l2, Prng};

fn default_dispatcher() -> Arc<rsla::backend::Dispatcher> {
    // Wires the PJRT runtime (artifacts built by `make artifacts`);
    // falls back to native-only if artifacts are missing so the test
    // suite stays runnable without them.
    rsla::backend::Dispatcher::default_full()
}

// ---------------------------------------------------------------------
// 1. Full solve path: assembly -> dispatch -> solve, every backend that
//    claims support must agree with the direct reference.
// ---------------------------------------------------------------------

#[test]
fn all_backends_agree_on_poisson() {
    let g = 32;
    let n = g * g;
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let disp = default_dispatcher();
    if disp.backend_names().len() < 5 {
        eprintln!("skipping: PJRT artifacts unavailable, xla backends not registered");
        return;
    }
    let a = SparseTensor::from_csr(sys.matrix.clone()).with_dispatcher(disp.clone());
    let mut rng = Prng::new(7);
    let b = rng.normal_vec(n);

    let reference = {
        let f = rsla::direct::SparseLu::factor(&sys.matrix).unwrap();
        f.solve(&b).unwrap()
    };

    let mut solved = 0;
    for name in disp.backend_names() {
        let opts = SolveOpts {
            backend: Some(name.to_string()),
            device: if name.starts_with("xla") {
                Device::Accel
            } else {
                Device::Cpu
            },
            tol: 1e-11,
            ..Default::default()
        };
        match a.solve_full(0, &b, &opts) {
            Ok(out) => {
                assert!(
                    rel_l2(&out.x, &reference) < 1e-6,
                    "backend {name} disagrees with direct reference: rel_l2={}",
                    rel_l2(&out.x, &reference)
                );
                solved += 1;
            }
            // a backend may legitimately refuse an operator FORM it does
            // not serve (xla-hybrid is stencil-only); anything else is a
            // real failure.
            Err(rsla::Error::BackendUnavailable { .. }) => {}
            Err(e) => panic!("backend {name} failed on supported problem: {e}"),
        }
    }
    assert!(
        solved >= 4,
        "expected at least 4 backends to solve a CSR Poisson system, got {solved}"
    );

    // xla-hybrid serves the STENCIL operator form: same system, same answer.
    let a_st =
        SparseTensor::from_stencil(sys.coeffs.clone()).with_dispatcher(disp.clone());
    let opts = SolveOpts {
        backend: Some("xla-hybrid".into()),
        device: Device::Accel,
        tol: 1e-11,
        ..Default::default()
    };
    match a_st.solve_full(0, &b, &opts) {
        Ok(out) => {
            assert!(
                rel_l2(&out.x, &reference) < 1e-6,
                "xla-hybrid disagrees: rel_l2={}",
                rel_l2(&out.x, &reference)
            );
        }
        Err(rsla::Error::BackendUnavailable { reason, .. }) => {
            panic!("xla-hybrid refused its own stencil form: {reason}")
        }
        Err(e) => panic!("xla-hybrid failed: {e}"),
    }
}

#[test]
fn auto_dispatch_picks_device_appropriate_backend() {
    let g = 24;
    let sys = poisson2d(g, None);
    let disp = default_dispatcher();
    let has_xla = disp.backend_names().iter().any(|n| n.starts_with("xla"));
    let a = SparseTensor::from_csr(sys.matrix.clone()).with_dispatcher(disp);
    let b = vec![1.0; g * g];

    let cpu = a.solve_full(0, &b, &SolveOpts::default()).unwrap();
    assert!(
        cpu.backend.starts_with("native"),
        "CPU device must route to a native backend, got {}",
        cpu.backend
    );

    let accel = a.solve_full(0, &b, &SolveOpts::on_accel()).unwrap();
    if has_xla {
        assert!(
            accel.backend.starts_with("xla"),
            "Accel device must route to an xla backend, got {}",
            accel.backend
        );
    } else {
        // no artifacts: the Accel chain must still serve via the
        // native fallbacks rather than erroring
        assert!(accel.backend.starts_with("native"));
    }
    assert!(rel_l2(&cpu.x, &accel.x) < 1e-6);
}

// ---------------------------------------------------------------------
// 2. Adjoint gradients through the full dispatch path (including the
//    PJRT-backed forward) match finite differences.
// ---------------------------------------------------------------------

#[test]
fn adjoint_gradients_through_xla_backend_match_fd() {
    let g = 16;
    let n = g * g;
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let a = SparseTensor::from_csr(sys.matrix.clone()).with_dispatcher(default_dispatcher());
    let mut rng = Prng::new(1);
    let b0 = rng.normal_vec(n);

    let opts = SolveOpts {
        device: Device::Accel,
        tol: 1e-12,
        ..Default::default()
    };

    let tape = Tape::new();
    let vals = tape.leaf_vec(sys.matrix.vals.clone());
    let bv = tape.leaf_vec(b0.clone());
    let x = a.solve_ad(&tape, vals, bv, &opts).unwrap();
    let loss = tape.dot(x, x);
    let grads = tape.backward(loss);
    let db = grads.vec(bv).clone();

    let loss_of_b = |bb: &[f64]| {
        let x = a.solve(bb, &opts).unwrap();
        dot(&x, &x)
    };
    let chk = rsla::gradcheck::check_direction(loss_of_b, &b0, &db, 1e-6, 3, 3);
    assert!(
        chk.rel_error < 1e-5,
        "xla-path adjoint gradient off: rel={}",
        chk.rel_error
    );
}

#[test]
fn solve_graph_is_o1_nodes_regardless_of_tolerance() {
    // Tight tolerance => many CG iterations; the tape must not grow.
    let g = 24;
    let sys = poisson2d(g, None);
    let a = SparseTensor::from_csr(sys.matrix.clone());
    let b0 = vec![1.0; g * g];

    let count_nodes = |tol: f64| {
        let tape = Tape::new();
        let vals = tape.leaf_vec(sys.matrix.vals.clone());
        let bv = tape.leaf_vec(b0.clone());
        let opts = SolveOpts {
            method: Method::Cg,
            backend: Some("native-iter".into()),
            tol,
            ..Default::default()
        };
        let x = a.solve_ad(&tape, vals, bv, &opts).unwrap();
        let _ = tape.dot(x, x);
        tape.node_count()
    };
    let loose = count_nodes(1e-2);
    let tight = count_nodes(1e-12);
    assert_eq!(
        loose, tight,
        "adjoint graph must be O(1) in iteration count"
    );
}

// ---------------------------------------------------------------------
// 3. Nonlinear + eigenvalue adjoints (paper Table 5 semantics).
// ---------------------------------------------------------------------

#[test]
fn nonlinear_solve_end_to_end_gradient() {
    // F(u; theta) = A u + u^2 - theta, loss = ||u||^2.
    use rsla::nonlinear::Residual;
    use rsla::sparse::Coo;

    struct Forced {
        a: Csr,
        theta: Vec<f64>,
    }
    impl Residual for Forced {
        fn dim(&self) -> usize {
            self.theta.len()
        }
        fn eval(&self, u: &[f64], out: &mut [f64]) {
            self.a.spmv(u, out);
            for i in 0..u.len() {
                out[i] += u[i] * u[i] - self.theta[i];
            }
        }
        fn jacobian(&self, u: &[f64]) -> Csr {
            let n = self.a.nrows;
            let mut coo = Coo::with_capacity(n, n, self.a.nnz() + n);
            for r in 0..n {
                let (cols, vals) = self.a.row(r);
                for (c, v) in cols.iter().zip(vals) {
                    coo.push(r, *c, *v);
                }
                coo.push(r, r, 2.0 * u[r]);
            }
            coo.to_csr()
        }
        fn vjp_theta(&self, _u: &[f64], lambda: &[f64]) -> Vec<f64> {
            lambda.iter().map(|l| -l).collect()
        }
    }

    let g = 10;
    let n = g * g;
    let sys = poisson2d(g, None);
    let a_mat = sys.matrix.clone();
    let mut rng = Prng::new(5);
    let theta0: Vec<f64> = rng.normal_vec(n).iter().map(|t| 1.0 + 0.1 * t).collect();

    let tape = Tape::new();
    let theta = tape.leaf_vec(theta0.clone());
    let factory: rsla::adjoint::ResidualFactory = {
        let a = a_mat.clone();
        std::rc::Rc::new(move |th: &[f64]| {
            Box::new(Forced {
                a: a.clone(),
                theta: th.to_vec(),
            }) as Box<dyn Residual>
        })
    };
    let opts = NewtonOpts::default();
    let (u, result) = rsla::adjoint::solve_nonlinear(&tape, factory, theta, &vec![0.0; n], &opts)
        .unwrap();
    assert!(result.converged, "Newton failed to converge");
    let loss = tape.dot(u, u);
    let grads = tape.backward(loss);
    let dtheta = grads.vec(theta).clone();

    // FD check
    let loss_of_theta = |th: &[f64]| {
        let f = Forced {
            a: a_mat.clone(),
            theta: th.to_vec(),
        };
        let r = rsla::nonlinear::newton(&f, &vec![0.0; n], &NewtonOpts::default());
        dot(&r.u, &r.u)
    };
    let chk = rsla::gradcheck::check_direction(loss_of_theta, &theta0, &dtheta, 1e-6, 3, 11);
    assert!(
        chk.rel_error < 1e-5,
        "nonlinear adjoint off: rel={}",
        chk.rel_error
    );
}

#[test]
fn eigsh_end_to_end_gradient() {
    let g = 12;
    let sys = poisson2d(g, None);
    let pattern = Pattern::of(&sys.matrix);
    let tape = Tape::new();
    let vals = tape.leaf_vec(sys.matrix.vals.clone());
    let opts = LobpcgOpts {
        tol: 1e-10,
        max_iters: 2000,
        seed: 0,
    };
    let (lams, res) = rsla::adjoint::eigsh(&tape, &pattern, vals, 3, &opts).unwrap();
    assert!(res.residuals.iter().all(|r| *r < 1e-6));
    // loss = sum of the k smallest eigenvalues
    let ones = tape.constant_vec(vec![1.0; 3]);
    let loss = tape.dot(lams, ones);
    let grads = tape.backward(loss);
    let dvals = grads.vec(vals).clone();

    let vals0 = sys.matrix.vals.clone();
    let loss_of_vals = |v: &[f64]| {
        let a = pattern.with_vals(v.to_vec());
        let precond = rsla::iterative::Jacobi::new(&a).unwrap();
        let r = rsla::eigen::lobpcg(
            &a,
            &precond as &dyn rsla::iterative::Precond,
            3,
            &LobpcgOpts {
                tol: 1e-10,
                max_iters: 2000,
                seed: 0,
            },
        );
        r.values.iter().sum::<f64>()
    };
    // Symmetric perturbation direction to stay in the symmetric manifold:
    // perturb via kappa would be cleaner, but a symmetric random direction
    // works since the pattern is symmetric.
    let chk =
        rsla::gradcheck::check_symmetric_direction(loss_of_vals, &pattern, &vals0, &dvals, 1e-6, 17);
    assert!(
        chk.rel_error < 1e-4,
        "eigsh adjoint off: rel={}",
        chk.rel_error
    );
}

// ---------------------------------------------------------------------
// 4. Distributed: forward + adjoint must equal single-process results,
//    and the transposed halo must be the exact adjoint of the forward.
// ---------------------------------------------------------------------

#[test]
fn distributed_solve_matches_single_process() {
    let g = 40;
    let n = g * g;
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let mut rng = Prng::new(2);
    let b = rng.normal_vec(n);

    let single = {
        let f = rsla::direct::SparseLu::factor(&sys.matrix).unwrap();
        f.solve(&b).unwrap()
    };

    for nparts in [2, 3, 4] {
        for strat in [
            PartitionStrategy::Contiguous,
            PartitionStrategy::Rcb,
            PartitionStrategy::GreedyBfs,
        ] {
            let coords = sys.coords.clone();
            let d = DSparseTensor::from_global(&sys.matrix, Some(&coords), nparts, strat).unwrap();
            let (x, reports) = d
                .solve(
                    &b,
                    &DistIterOpts {
                        tol: 1e-11,
                        max_iters: 20_000,
                ..Default::default()
            },
                )
                .unwrap();
            assert!(
                rel_l2(&x, &single) < 1e-7,
                "dist solve ({nparts} parts, {strat:?}) off: {}",
                rel_l2(&x, &single)
            );
            assert!(reports.iter().all(|r| r.converged));
            assert!(reports.iter().all(|r| r.bytes_sent > 0 || nparts == 1));
        }
    }
}

#[test]
fn distributed_adjoint_gradients_match_serial_adjoint() {
    let g = 24;
    let n = g * g;
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let mut rng = Prng::new(3);
    let b = rng.normal_vec(n);
    let w = rng.normal_vec(n); // loss = <w, x>

    // serial adjoint: lambda = A^{-T} w, db = lambda, dA_ij = -lambda_i x_j
    let f = rsla::direct::SparseLu::factor(&sys.matrix).unwrap();
    let x_ref = f.solve(&b).unwrap();
    let lambda_ref = f.solve_t(&w).unwrap();

    let d = DSparseTensor::from_global(&sys.matrix, None, 3, PartitionStrategy::Contiguous)
        .unwrap();
    let (x, db, triplets) = d
        .solve_adjoint(
            &b,
            &w,
            &DistIterOpts {
                tol: 1e-12,
                max_iters: 40_000,
                ..Default::default()
            },
        )
        .unwrap();
    assert!(rel_l2(&x, &x_ref) < 1e-7);
    assert!(rel_l2(&db, &lambda_ref) < 1e-7);
    // every emitted triplet must match the analytic dA_ij = -lambda_i x_j
    assert_eq!(triplets.len(), sys.matrix.nnz());
    let (mut num, mut den) = (0.0, 0.0);
    for &(r, c, v) in &triplets {
        let want = -lambda_ref[r] * x_ref[c];
        num += (v - want) * (v - want);
        den += want * want;
    }
    assert!(
        (num / den.max(1e-300)).sqrt() < 1e-6,
        "distributed dA off: {}",
        (num / den).sqrt()
    );
    let _ = n;
}

// ---------------------------------------------------------------------
// 5. Coordinator service: concurrent mixed-pattern workload.
// ---------------------------------------------------------------------

#[test]
fn coordinator_serves_concurrent_mixed_workload() {
    let disp = default_dispatcher();
    // one worker + a wide batching window so same-pattern requests are
    // guaranteed to coalesce regardless of build profile (debug solves
    // are slow enough to outlive the default 2 ms window)
    let service = SolveService::start(
        disp,
        ServiceConfig {
            workers: 1,
            batch: rsla::coordinator::BatchPolicy {
                max_batch: 16,
                window: std::time::Duration::from_millis(100),
            },
        },
    );

    let mut rng = Prng::new(9);
    let mut receivers = Vec::new();
    let mut expected = Vec::new();
    for i in 0..24 {
        let g = 8 + (i % 3) * 4; // three distinct patterns
        let sys = poisson2d(g, None);
        let b = rng.normal_vec(g * g);
        let f = rsla::direct::SparseLu::factor(&sys.matrix).unwrap();
        expected.push(f.solve(&b).unwrap());
        receivers.push(service.submit(sys.matrix.clone(), b, SolveOpts::default()));
    }
    for (rx, want) in receivers.into_iter().zip(&expected) {
        let resp = rx.recv().expect("service dropped request");
        let x = resp.outcome.expect("solve failed").x;
        assert!(rel_l2(&x, want) < 1e-7);
    }
    let stats = service.stats();
    assert_eq!(stats.completed, 24);
    assert!(
        stats.batches < 24,
        "same-pattern requests should batch (got {} batches)",
        stats.batches
    );
    service.shutdown();
}

// ---------------------------------------------------------------------
// 6. Batched solves: shared pattern reuses one factorization; distinct
//    patterns dispatch independently (SparseTensorList).
// ---------------------------------------------------------------------

#[test]
fn batched_shared_pattern_and_tensor_list() {
    let g = 16;
    let n = g * g;
    let sys = poisson2d(g, None);
    let pat = Pattern::of(&sys.matrix);
    let mut rng = Prng::new(4);

    // shared-pattern batch: scale the values per batch element
    let batch = 4;
    let vals: Vec<Vec<f64>> = (0..batch)
        .map(|i| {
            sys.matrix
                .vals
                .iter()
                .map(|v| v * (1.0 + 0.1 * i as f64))
                .collect()
        })
        .collect();
    let a = SparseTensor::batched(pat.clone(), vals.clone()).unwrap();
    let bs: Vec<Vec<f64>> = (0..batch).map(|_| rng.normal_vec(n)).collect();
    let xs = a.solve_batch(&bs, &SolveOpts::default()).unwrap();
    for i in 0..batch {
        let ai = pat.with_vals(vals[i].clone());
        assert!(rel_l2(&ai.matvec(&xs[i]), &bs[i]) < 1e-8);
    }

    // distinct patterns: a list of different grids
    let mats: Vec<Csr> = [8usize, 12, 16]
        .iter()
        .map(|&gi| poisson2d(gi, None).matrix)
        .collect();
    let sizes: Vec<usize> = mats.iter().map(|m| m.nrows).collect();
    let list = SparseTensorList::from_csrs(mats.clone());
    let bs: Vec<Vec<f64>> = sizes.iter().map(|&ni| rng.normal_vec(ni)).collect();
    let xs = list.solve(&bs, &SolveOpts::default()).unwrap();
    for i in 0..mats.len() {
        assert!(rel_l2(&mats[i].matvec(&xs[i]), &bs[i]) < 1e-8);
    }
}

// ---------------------------------------------------------------------
// 7. The paper's Fig. 3 loop, compressed: recover kappa on a 16x16 grid
//    from observations alone, through the adjoint solve, with Adam.
// ---------------------------------------------------------------------

#[test]
fn inverse_coefficient_learning_recovers_kappa() {
    let g = 16;
    let asm = PoissonAssembler::new(g);
    let kappa_true = kappa_star(g);
    let sys = poisson2d(g, Some(&kappa_true));
    let f_rhs = vec![1.0; g * g];
    let u_obs = {
        let f = rsla::direct::SparseLu::factor(&sys.matrix).unwrap();
        f.solve(&f_rhs).unwrap()
    };

    // theta -> kappa = softplus(theta); start from kappa ~ 1.0
    let n_k = g * g;
    let mut theta = vec![0.5413_f64; n_k]; // softplus(0.5413) ~ 1.0
    let mut adam = Adam::new(n_k, 5e-2);
    let mut last_loss = f64::INFINITY;

    for step in 0..600 {
        let tape = Tape::new();
        let th = tape.leaf_vec(theta.clone());
        let kappa = tape.softplus(th);
        let vals = asm.assemble(&tape, kappa);
        let bv = tape.constant_vec(f_rhs.clone());
        let x = rsla::adjoint::solve_linear(
            &tape,
            &asm.pattern,
            vals,
            bv,
            &rsla::adjoint::native_solver(),
        )
        .unwrap();
        let obs = tape.constant_vec(u_obs.clone());
        let diff = tape.sub(x, obs);
        let misfit = tape.dot(diff, diff);
        let reg = asm.smoothness(&tape, kappa);
        let reg_scaled = tape.scale_const_s(1e-3 / n_k as f64, reg);
        let loss = tape.add_ss(misfit, reg_scaled);
        let loss_val = tape.scalar_of(loss);
        let grads = tape.backward(loss);
        let dtheta = grads.vec(th).clone();
        adam.step(&mut theta, &dtheta);
        if step % 50 == 0 {
            last_loss = loss_val;
        }
    }

    let kappa_rec: Vec<f64> = theta.iter().map(|t| util::softplus(*t)).collect();
    let err = rel_l2(&kappa_rec, &kappa_true);
    assert!(
        err < 3e-2,
        "kappa recovery too poor after 600 steps: rel_l2={err}, last_loss={last_loss}"
    );
    // forward solution must match observations closely
    let sys_rec = poisson2d(g, Some(&kappa_rec));
    let f = rsla::direct::SparseLu::factor(&sys_rec.matrix).unwrap();
    let u_rec = f.solve(&f_rhs).unwrap();
    assert!(rel_l2(&u_rec, &u_obs) < 1e-3);
}

// ---------------------------------------------------------------------
// 8. Memory-budget OOM semantics (Table 3/4 "OOM" rows are budget
//    violations, not crashes).
// ---------------------------------------------------------------------

#[test]
fn direct_backend_oom_is_a_clean_error_and_dispatch_falls_back() {
    let g = 64; // 4096 unknowns: LU fill exceeds a tiny budget
    let sys = poisson2d(g, None);
    let a = SparseTensor::from_csr(sys.matrix.clone());
    let b = vec![1.0; g * g];

    // forcing the direct backend with a tiny budget must error cleanly
    let opts = SolveOpts {
        backend: Some("native-direct".into()),
        host_mem_budget: 64 << 10, // 64 KiB
        ..Default::default()
    };
    let err = a.solve(&b, &opts).unwrap_err();
    let msg = format!("{err}");
    assert!(
        msg.to_lowercase().contains("memory") || msg.to_lowercase().contains("budget"),
        "expected an OOM/budget error, got: {msg}"
    );

    // auto-dispatch with the same budget must fall back to iterative
    let opts = SolveOpts {
        host_mem_budget: 64 << 10,
        ..Default::default()
    };
    let out = a.solve_full(0, &b, &opts).unwrap();
    assert_eq!(out.backend, "native-iter");
    assert!(rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-7);
}

// ---------------------------------------------------------------------
// 9. Utility invariants that glue the layers: norm2/dot consistency.
// ---------------------------------------------------------------------

#[test]
fn util_consistency() {
    let mut rng = Prng::new(0);
    let v = rng.normal_vec(1000);
    assert!((norm2(&v).powi(2) - dot(&v, &v)).abs() < 1e-9 * dot(&v, &v).max(1.0));
}
