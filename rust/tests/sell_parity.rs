//! Property tests for the SELL-C-σ format and the fused multi-vector
//! kernels: random and pathological matrices, CSR↔SELL round trips over
//! a grid of (chunk, σ), and spmv / spmv_t / fused-k parity against the
//! CSR reference within a 1-ulp-scale tolerance.
//!
//! These pins back the format swap in `TunedOp`: a solver that is handed
//! SELL instead of CSR must see the same operator to within rounding of
//! the padded `+0.0` tail, on EVERY row-length distribution the cost
//! model can route there — including the ones it would normally reject
//! (power-law, empty rows), because `Sell::from_csr` has to be total
//! even where it is not profitable.

use rsla::sparse::kernels::spmv_block;
use rsla::sparse::sell::{DEFAULT_CHUNK, DEFAULT_SIGMA};
use rsla::sparse::{choose_format, Csr, Sell, TunedOp};
use rsla::util::Prng;

/// (chunk, σ) grid: degenerate σ=1, non-divisor chunk heights, the
/// vectorized 4/8/16 paths, and chunk > nrows.
const COMBOS: [(usize, usize); 7] = [(1, 1), (3, 1), (4, 16), (8, 64), (16, 7), (5, 2), (128, 64)];

fn assert_close(y: &[f64], yref: &[f64], ctx: &str) {
    assert_eq!(y.len(), yref.len(), "{ctx}: length mismatch");
    for (i, (yi, ri)) in y.iter().zip(yref).enumerate() {
        assert!(
            (yi - ri).abs() <= 1e-12 * ri.abs().max(1.0),
            "{ctx}: row {i}: {yi} vs {ri}"
        );
    }
}

/// Random sparse matrix: `per_row_max` bounds each row's length, drawn
/// uniformly (including 0, so empty rows occur naturally).
fn random_csr(rng: &mut Prng, nrows: usize, ncols: usize, per_row_max: usize) -> Csr {
    let mut indptr = vec![0usize];
    let mut indices = Vec::new();
    let mut vals = Vec::new();
    for _ in 0..nrows {
        let len = (rng.normal().abs() * per_row_max as f64) as usize % (per_row_max + 1);
        let mut cols = rng.choose_distinct(ncols, len.min(ncols));
        cols.sort_unstable();
        for c in cols {
            indices.push(c);
            vals.push(rng.normal());
        }
        indptr.push(indices.len());
    }
    Csr {
        nrows,
        ncols,
        indptr,
        indices,
        vals,
    }
    .debug_validate()
}

/// Every row empty except a handful — the min_len = 0 edge the cost
/// model and the chunk-width logic both have to survive.
fn mostly_empty(n: usize) -> Csr {
    let mut indptr = vec![0usize];
    let mut indices = Vec::new();
    let mut vals = Vec::new();
    for r in 0..n {
        if r % 17 == 3 {
            indices.push(r);
            vals.push(2.0 + r as f64);
        }
        indptr.push(indices.len());
    }
    Csr {
        nrows: n,
        ncols: n,
        indptr,
        indices,
        vals,
    }
    .debug_validate()
}

/// One fully dense row among singletons: the worst case for unsorted
/// ELL padding, the case σ-sorting exists to contain.
fn single_dense_row(n: usize, dense_at: usize) -> Csr {
    let mut indptr = vec![0usize];
    let mut indices = Vec::new();
    let mut vals = Vec::new();
    for r in 0..n {
        if r == dense_at {
            for c in 0..n {
                indices.push(c);
                vals.push(1.0 / (1.0 + c as f64));
            }
        } else {
            indices.push(r);
            vals.push(1.0 + r as f64);
        }
        indptr.push(indices.len());
    }
    Csr {
        nrows: n,
        ncols: n,
        indptr,
        indices,
        vals,
    }
    .debug_validate()
}

/// Hub-and-spoke degree skew (the cost model's stay-CSR case).
fn power_law(rng: &mut Prng, n: usize) -> Csr {
    let mut indptr = vec![0usize];
    let mut indices = Vec::new();
    let mut vals = Vec::new();
    for r in 0..n {
        let len = if r % 53 == 0 { n / 3 } else { 1 + r % 3 };
        let mut cols = rng.choose_distinct(n, len.min(n));
        cols.sort_unstable();
        for c in cols {
            indices.push(c);
            vals.push(rng.normal());
        }
        indptr.push(indices.len());
    }
    Csr {
        nrows: n,
        ncols: n,
        indptr,
        indices,
        vals,
    }
    .debug_validate()
}

fn test_matrices() -> Vec<(String, Csr)> {
    let mut rng = Prng::new(42);
    let mut out = vec![
        (
            "poisson2d(11)".to_string(),
            rsla::sparse::poisson::poisson2d(11, None).matrix,
        ),
        ("mostly_empty(100)".to_string(), mostly_empty(100)),
        ("single_dense_row(96)".to_string(), single_dense_row(96, 37)),
        ("power_law(211)".to_string(), power_law(&mut rng, 211)),
        (
            "rect 60x90".to_string(),
            random_csr(&mut rng, 60, 90, 7),
        ),
        (
            "rect 90x60".to_string(),
            random_csr(&mut rng, 90, 60, 5),
        ),
    ];
    for trial in 0..4u64 {
        let mut rng = Prng::new(100 + trial);
        let n = 40 + 23 * trial as usize;
        out.push((format!("random n={n}"), random_csr(&mut rng, n, n, 9)));
    }
    out
}

#[test]
fn round_trip_is_exact_on_every_matrix_and_combo() {
    for (name, a) in test_matrices() {
        for &(chunk, sigma) in &COMBOS {
            let s = Sell::from_csr(&a, chunk, sigma);
            assert!(
                s.validate().is_ok(),
                "{name} chunk={chunk} sigma={sigma}: {:?}",
                s.validate()
            );
            assert_eq!(s.to_csr(), a, "{name} chunk={chunk} sigma={sigma}");
            assert_eq!(s.nnz(), a.nnz(), "{name}");
        }
        // ELL degenerate form round-trips too
        let e = Sell::ell(&a);
        assert!(e.validate().is_ok(), "{name} ell");
        assert_eq!(e.to_csr(), a, "{name} ell");
    }
}

#[test]
fn spmv_and_spmv_t_match_csr_on_every_combo() {
    for (name, a) in test_matrices() {
        let mut rng = Prng::new(7);
        let x = rng.normal_vec(a.ncols);
        let xt = rng.normal_vec(a.nrows);
        let mut yref = vec![0.0; a.nrows];
        a.spmv(&x, &mut yref);
        let mut ytref = vec![0.0; a.ncols];
        a.spmv_t(&xt, &mut ytref);
        for &(chunk, sigma) in &COMBOS {
            let s = Sell::from_csr(&a, chunk, sigma);
            let mut y = vec![f64::NAN; a.nrows]; // spmv overwrites every row
            s.spmv(&x, &mut y);
            assert_close(&y, &yref, &format!("{name} spmv chunk={chunk} sigma={sigma}"));
            let mut yt = vec![0.0; a.ncols];
            s.spmv_t(&xt, &mut yt);
            assert_close(
                &yt,
                &ytref,
                &format!("{name} spmv_t chunk={chunk} sigma={sigma}"),
            );
        }
    }
}

#[test]
fn fused_block_spmv_matches_k_scalar_passes() {
    for (name, a) in test_matrices() {
        let mut rng = Prng::new(13);
        for k in [1usize, 2, 4, 8] {
            let cols: Vec<Vec<f64>> = (0..k).map(|_| rng.normal_vec(a.ncols)).collect();
            let mut xb = vec![0.0; a.ncols * k];
            for (j, c) in cols.iter().enumerate() {
                for (i, v) in c.iter().enumerate() {
                    xb[i * k + j] = *v;
                }
            }
            // CSR fused kernel: bitwise per-column contract
            let mut yb = vec![0.0; a.nrows * k];
            spmv_block(&a, &xb, &mut yb, k);
            for (j, c) in cols.iter().enumerate() {
                let mut yref = vec![0.0; a.nrows];
                a.spmv(c, &mut yref);
                for i in 0..a.nrows {
                    assert_eq!(
                        yb[i * k + j].to_bits(),
                        yref[i].to_bits(),
                        "{name} csr fused k={k} col={j} row={i}"
                    );
                }
            }
            // SELL fused kernel: 1-ulp-scale tolerance (padding tail)
            let s = Sell::from_csr(&a, DEFAULT_CHUNK, DEFAULT_SIGMA);
            let mut ys = vec![0.0; a.nrows * k];
            s.spmv_block(&xb, &mut ys, k);
            for (j, c) in cols.iter().enumerate() {
                let mut yref = vec![0.0; a.nrows];
                a.spmv(c, &mut yref);
                let got: Vec<f64> = (0..a.nrows).map(|i| ys[i * k + j]).collect();
                assert_close(&got, &yref, &format!("{name} sell fused k={k} col={j}"));
            }
        }
    }
}

#[test]
fn tuned_op_agrees_with_csr_regardless_of_choice() {
    for (name, a) in test_matrices() {
        if a.nrows != a.ncols {
            continue; // TunedOp serves square solver operators
        }
        let t = TunedOp::new(&a, None);
        let report = choose_format(&a);
        assert_eq!(t.format(), report.choice, "{name}");
        let mut rng = Prng::new(3);
        let x = rng.normal_vec(a.ncols);
        let mut x_ext = x.clone();
        let mut y = vec![0.0; a.nrows];
        rsla::krylov::LinearOperator::apply(&t, &mut x_ext, &mut y);
        assert_close(&y, &a.matvec(&x), &format!("{name} tuned apply"));
    }
}

#[test]
fn cost_model_decisions_track_occupancy_threshold() {
    // regular stencil → SELL; skew/empty → CSR; and on every matrix the
    // reported occupancy must match the conversion it predicts.
    let poisson = rsla::sparse::poisson::poisson2d(16, None).matrix;
    assert_eq!(
        choose_format(&poisson).choice,
        rsla::sparse::FormatChoice::Sell
    );
    let mut rng = Prng::new(5);
    let skew = power_law(&mut rng, 212);
    assert_eq!(choose_format(&skew).choice, rsla::sparse::FormatChoice::Csr);
    // nnz = 0 can never pay for a conversion
    let empty = Csr {
        nrows: 8,
        ncols: 8,
        indptr: vec![0; 9],
        indices: vec![],
        vals: vec![],
    }
    .debug_validate();
    assert_eq!(
        choose_format(&empty).choice,
        rsla::sparse::FormatChoice::Csr
    );
    for (name, a) in test_matrices() {
        let report = choose_format(&a);
        let s = Sell::from_csr(&a, DEFAULT_CHUNK, DEFAULT_SIGMA);
        assert!(
            (report.occupancy - s.occupancy()).abs() < 1e-12,
            "{name}: dry-run occupancy {} vs actual {}",
            report.occupancy,
            s.occupancy()
        );
    }
}
