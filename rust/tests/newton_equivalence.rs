//! Iterate-trajectory equivalence for the damped-Newton refactor: the
//! assembled-Jacobian `newton` and matrix-free `newton_krylov` outer
//! loops were collapsed into ONE driver (`damped_newton` over a
//! `NewtonFlow`), so the two control flows cannot diverge.  These tests
//! pin that claim against FROZEN copies of the pre-refactor loops:
//! same iterate trajectory, bitwise — same `u`, same iteration count,
//! same linear-solve count, same residual norm.

use rsla::factor_cache::cached_direct_solve;
use rsla::iterative::{Identity, IterOpts};
use rsla::krylov::{self, gdot, Communicator, LinearOperator, NullComm};
use rsla::nonlinear::{
    examples::QuadPoisson, newton, newton_krylov, newton_with_step, KrylovResidual, NewtonOpts,
    NonlinearResult, Residual,
};
use rsla::sparse::poisson::poisson2d;
use rsla::sparse::Csr;
use rsla::util::{norm2, Prng};

// ---------------------------------------------------------------------
// Frozen pre-refactor loops (verbatim from the code before the shared
// damped_newton driver existed).  Do not "improve" these: they are the
// reference semantics.
// ---------------------------------------------------------------------

fn frozen_newton(f: &dyn Residual, u0: &[f64], opts: &NewtonOpts) -> NonlinearResult {
    let n = f.dim();
    assert_eq!(u0.len(), n);
    let mut u = u0.to_vec();
    let mut fu = vec![0.0; n];
    f.eval(&u, &mut fu);
    let mut fnorm = norm2(&fu);
    let mut linear_solves = 0;

    let mut iters = 0;
    while iters < opts.max_iters && (opts.fixed_iters || fnorm > opts.tol) {
        let j = f.jacobian(&u);
        let rhs: Vec<f64> = fu.iter().map(|x| -x).collect();
        let du = match cached_direct_solve(&j, &rhs) {
            Ok(d) => d,
            Err(_) => break,
        };
        linear_solves += 1;
        let mut t = 1.0;
        let mut accepted = false;
        for _ in 0..=opts.max_halvings {
            let trial: Vec<f64> = u.iter().zip(&du).map(|(ui, di)| ui + t * di).collect();
            let mut ftrial = vec![0.0; n];
            f.eval(&trial, &mut ftrial);
            let fn_trial = norm2(&ftrial);
            if fn_trial < fnorm || opts.max_halvings == 0 {
                u = trial;
                fu = ftrial;
                fnorm = fn_trial;
                accepted = true;
                break;
            }
            t *= 0.5;
        }
        if !accepted {
            for i in 0..n {
                u[i] += du[i];
            }
            f.eval(&u, &mut fu);
            fnorm = norm2(&fu);
        }
        iters += 1;
    }

    NonlinearResult {
        converged: fnorm <= opts.tol,
        u,
        iters,
        residual_norm: fnorm,
        linear_solves,
    }
}

struct FrozenJvOp<'a> {
    f: &'a dyn KrylovResidual,
    u_ext: &'a [f64],
}

impl LinearOperator for FrozenJvOp<'_> {
    fn n_own(&self) -> usize {
        self.f.n_own()
    }

    fn n_ext(&self) -> usize {
        self.f.n_ext()
    }

    fn apply(&self, x_ext: &mut [f64], y_own: &mut [f64]) {
        self.f.jv(self.u_ext, x_ext, y_own);
    }
}

fn frozen_newton_krylov(
    f: &dyn KrylovResidual,
    u0_own: &[f64],
    comm: &dyn Communicator,
    opts: &NewtonOpts,
    inner: &IterOpts,
) -> NonlinearResult {
    let n = f.n_own();
    assert_eq!(u0_own.len(), n);
    let n_ext = f.n_ext();
    let mut u_ext = vec![0.0; n_ext];
    u_ext[..n].copy_from_slice(u0_own);
    let mut fu = vec![0.0; n];
    f.eval(&mut u_ext, &mut fu);
    let mut fnorm = gdot(comm, &fu, &fu).sqrt();
    let mut linear_solves = 0;
    let mut trial_ext = vec![0.0; n_ext];

    let mut iters = 0;
    while iters < opts.max_iters && (opts.fixed_iters || fnorm > opts.tol) {
        let rhs: Vec<f64> = fu.iter().map(|x| -x).collect();
        let res = {
            let jop = FrozenJvOp { f, u_ext: &u_ext };
            krylov::gmres(&jop, &rhs, &Identity, 50, comm, inner, None)
        };
        linear_solves += 1;
        let du = res.x;
        let local_bad = if du.iter().any(|d| !d.is_finite()) {
            1.0
        } else {
            0.0
        };
        if comm.all_reduce_sum(local_bad) > 0.0 {
            break;
        }
        let mut t = 1.0;
        let mut accepted = false;
        for _ in 0..=opts.max_halvings {
            for i in 0..n {
                trial_ext[i] = u_ext[i] + t * du[i];
            }
            let mut ftrial = vec![0.0; n];
            f.eval(&mut trial_ext, &mut ftrial);
            let fn_trial = gdot(comm, &ftrial, &ftrial).sqrt();
            if fn_trial < fnorm || opts.max_halvings == 0 {
                u_ext.copy_from_slice(&trial_ext);
                fu = ftrial;
                fnorm = fn_trial;
                accepted = true;
                break;
            }
            t *= 0.5;
        }
        if !accepted {
            for i in 0..n {
                u_ext[i] += du[i];
            }
            f.eval(&mut u_ext, &mut fu);
            fnorm = gdot(comm, &fu, &fu).sqrt();
        }
        iters += 1;
    }

    NonlinearResult {
        converged: fnorm <= opts.tol,
        u: u_ext[..n].to_vec(),
        iters,
        residual_norm: fnorm,
        linear_solves,
    }
}

// ---------------------------------------------------------------------
// The pins
// ---------------------------------------------------------------------

fn problem(seed: u64, g: usize) -> QuadPoisson {
    let sys = poisson2d(g, None);
    let mut rng = Prng::new(seed);
    let n = g * g;
    QuadPoisson {
        a: sys.matrix,
        // large forcing so the first Newton step overshoots and the
        // backtracking branch is actually exercised by the trajectory
        f: (0..n).map(|_| 5.0 + 10.0 * rng.uniform()).collect(),
    }
}

fn assert_same_trajectory(got: &NonlinearResult, want: &NonlinearResult, label: &str) {
    assert_eq!(got.iters, want.iters, "{label}: iteration count diverged");
    assert_eq!(
        got.linear_solves, want.linear_solves,
        "{label}: linear-solve count diverged"
    );
    assert_eq!(got.converged, want.converged, "{label}: converged flag diverged");
    assert_eq!(
        got.residual_norm.to_bits(),
        want.residual_norm.to_bits(),
        "{label}: residual norm diverged ({} vs {})",
        got.residual_norm,
        want.residual_norm
    );
    assert_eq!(got.u.len(), want.u.len());
    for (i, (a, b)) in got.u.iter().zip(&want.u).enumerate() {
        assert_eq!(
            a.to_bits(),
            b.to_bits(),
            "{label}: iterate diverged at entry {i} ({a} vs {b})"
        );
    }
}

#[test]
fn assembled_newton_matches_frozen_loop_bitwise() {
    let f = problem(1, 8);
    let u0 = vec![0.0; 64];
    for opts in [
        NewtonOpts::default(),
        NewtonOpts {
            max_halvings: 0,
            ..Default::default()
        },
        NewtonOpts {
            fixed_iters: true,
            max_iters: 3,
            ..Default::default()
        },
    ] {
        let want = frozen_newton(&f, &u0, &opts);
        let got = newton(&f, &u0, &opts);
        assert_same_trajectory(&got, &want, "newton");
        assert!(want.linear_solves > 0);
    }
}

#[test]
fn newton_krylov_matches_frozen_loop_bitwise() {
    let f = problem(2, 8);
    let u0 = vec![0.0; 64];
    let inner = IterOpts {
        tol: 1e-12,
        max_iters: 400,
        ..Default::default()
    };
    for opts in [
        NewtonOpts::default(),
        NewtonOpts {
            fixed_iters: true,
            max_iters: 3,
            ..Default::default()
        },
    ] {
        let want = frozen_newton_krylov(&f, &u0, &NullComm, &opts, &inner);
        let got = newton_krylov(&f, &u0, &NullComm, &opts, &inner);
        assert_same_trajectory(&got, &want, "newton_krylov");
    }
}

#[test]
fn newton_with_step_is_the_engine_instantiation_of_the_same_loop() {
    // the engine's workers hand Newton a shard-local step solver; with
    // an equivalent step (the same cached direct solve) the trajectory
    // must be identical to plain `newton`
    let f = problem(3, 7);
    let u0 = vec![0.0; 49];
    let opts = NewtonOpts::default();
    let want = newton(&f, &u0, &opts);
    let mut steps = 0usize;
    let mut step = |j: &Csr, rhs: &[f64]| {
        steps += 1;
        cached_direct_solve(j, rhs).ok()
    };
    let got = newton_with_step(&f, &u0, &opts, &mut step);
    assert_same_trajectory(&got, &want, "newton_with_step");
    assert_eq!(steps, want.linear_solves, "step solver called once per solve");
}

/// A residual whose Jacobian-vector product is non-finite: the GMRES
/// step degenerates immediately, exercising the early-break path.
struct NanJv;

impl KrylovResidual for NanJv {
    fn n_own(&self) -> usize {
        4
    }

    fn eval(&self, _u_ext: &mut [f64], out_own: &mut [f64]) {
        out_own.fill(1.0); // never converges
    }

    fn jv(&self, _u_ext: &[f64], _v_ext: &mut [f64], y_own: &mut [f64]) {
        y_own.fill(f64::NAN);
    }
}

#[test]
fn degenerate_krylov_step_matches_frozen_loop_including_solve_count() {
    // the pre-refactor loop counted the GMRES solve BEFORE the
    // non-finite check broke out; the unified driver must agree
    let u0 = vec![0.0; 4];
    let opts = NewtonOpts::default();
    let inner = IterOpts {
        tol: 1e-12,
        max_iters: 20,
        ..Default::default()
    };
    let want = frozen_newton_krylov(&NanJv, &u0, &NullComm, &opts, &inner);
    let got = newton_krylov(&NanJv, &u0, &NullComm, &opts, &inner);
    assert!(!want.converged);
    assert_same_trajectory(&got, &want, "degenerate newton_krylov");
}

#[test]
fn both_flows_agree_on_the_solution_itself() {
    // not bitwise across flows (different step solvers), but both must
    // land on the same root of F
    let f = problem(4, 8);
    let u0 = vec![0.0; 64];
    let opts = NewtonOpts {
        tol: 1e-11,
        ..Default::default()
    };
    let inner = IterOpts {
        tol: 1e-13,
        max_iters: 800,
        ..Default::default()
    };
    let a = newton(&f, &u0, &opts);
    let b = newton_krylov(&f, &u0, &NullComm, &opts, &inner);
    assert!(a.converged && b.converged);
    assert!(rsla::util::rel_l2(&a.u, &b.u) < 1e-8);
}
