//! Zero-span pin for the DISABLED tracer: a full mixed workload driven
//! through the engine with the tracer never enabled must leave the
//! global tracer completely empty — no spans, no events, no
//! convergence records, no drops.  This is the contract that makes the
//! always-compiled instrumentation free to leave in hot paths: when
//! off, every probe is one relaxed atomic load and a branch.
//!
//! This file is its own process (one `#[test]`), so the process-global
//! tracer is exclusively ours and no other test can have enabled it.

use std::sync::Arc;

use rsla::backend::Dispatcher;
use rsla::engine::{workload::MixedWorkload, Engine, EngineConfig, Ticket};
use rsla::trace::{self, Tracer};

#[test]
fn disabled_tracer_records_nothing_across_a_full_workload() {
    assert!(!trace::enabled(), "tracer must start disabled");

    let engine = Engine::start(
        Arc::new(Dispatcher::new(None)),
        EngineConfig {
            workers: 4,
            ..Default::default()
        },
    );
    let mut workload = MixedWorkload::new(&[12, 16], 17);
    workload.multi_rhs = 3;
    let mut tickets: Vec<Ticket> = Vec::new();
    // 40 consecutive specs cover all six job families (the workload
    // cycles kinds mod 10 / mod 20), so every instrumented code path
    // in the engine, cache, direct stack, and Krylov kernels runs.
    for i in 0..40 {
        tickets.push(engine.submit(workload.spec(i)).expect("admission"));
    }
    let mut failures = 0usize;
    for t in tickets {
        if t.wait().outcome.is_err() {
            failures += 1;
        }
    }
    engine.shutdown();

    let snap = Tracer::global().snapshot();
    assert!(
        snap.spans.is_empty(),
        "disabled tracer recorded {} spans",
        snap.spans.len()
    );
    assert!(
        snap.convs.is_empty(),
        "disabled tracer recorded {} convergence records",
        snap.convs.len()
    );
    assert_eq!(snap.dropped, 0);
    assert_eq!(failures, 0, "{failures} jobs failed");
}
