//! Property-based tests over the library's core invariants, using the
//! in-house PRNG property harness (util::proptest).

use rsla::adjoint::{native_solver, solve_linear, Transpose};
use rsla::autograd::Tape;
use rsla::direct::{direct_solve, EnvelopeCholesky, SparseLu};
use rsla::distributed::{run_ranks, DSparseTensor, DistIterOpts, PartitionStrategy};
use rsla::eigen::jacobi_eigh;
use rsla::iterative::{bicgstab, cg, gmres, Identity, IterOpts, Jacobi};
use rsla::sparse::graphs::{random_graph_laplacian, random_nonsymmetric, random_spd};
use rsla::sparse::poisson::{poisson2d, stencil_coeffs};
use rsla::sparse::{Coo, Csr, Pattern};
use rsla::util::proptest::{check, close};
use rsla::util::{self, dot, Prng};

fn random_csr(rng: &mut Prng, n: usize, per_row: usize) -> Csr {
    let mut coo = Coo::new(n, n);
    for r in 0..n {
        for c in rng.choose_distinct(n, per_row) {
            coo.push(r, c, rng.normal());
        }
    }
    coo.to_csr()
}

#[test]
fn prop_transpose_is_adjoint() {
    // <A x, y> == <x, A^T y> for random sparse matrices
    check("spmv transpose adjoint", 30, |rng| {
        let n = 10 + rng.below(60);
        let per_row = 1 + rng.below(5);
        let a = random_csr(rng, n, per_row);
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let ax = a.matvec(&x);
        let mut aty = vec![0.0; n];
        a.spmv_t(&y, &mut aty);
        let lhs = dot(&ax, &y);
        let rhs = dot(&x, &aty);
        if (lhs - rhs).abs() > 1e-9 * (1.0 + lhs.abs()) {
            return Err(format!("{lhs} vs {rhs}"));
        }
        Ok(())
    });
}

#[test]
fn prop_coo_to_csr_preserves_matvec() {
    check("coo->csr matvec equivalence", 25, |rng| {
        let n = 5 + rng.below(40);
        let mut coo = Coo::new(n, n);
        let entries = n * (1 + rng.below(4));
        for _ in 0..entries {
            coo.push(rng.below(n), rng.below(n), rng.normal());
        }
        let x = rng.normal_vec(n);
        // dense reference straight from triplets
        let mut want = vec![0.0; n];
        for k in 0..coo.nnz() {
            want[coo.rows[k]] += coo.vals[k] * x[coo.cols[k]];
        }
        close(&coo.to_csr().matvec(&x), &want, 1e-10)
    });
}

#[test]
fn prop_lu_reconstructs_solve() {
    check("LU solve residual", 20, |rng| {
        let n = 10 + rng.below(50);
        let per_row = 2 + rng.below(4);
        let a = random_nonsymmetric(rng, n, per_row);
        let b = rng.normal_vec(n);
        let f = SparseLu::factor(&a).map_err(|e| e.to_string())?;
        let x = f.solve(&b).map_err(|e| e.to_string())?;
        if util::rel_l2(&a.matvec(&x), &b) > 1e-8 {
            return Err("residual too large".into());
        }
        // transpose solve too
        let xt = f.solve_t(&b).map_err(|e| e.to_string())?;
        let mut atx = vec![0.0; n];
        a.spmv_t(&xt, &mut atx);
        if util::rel_l2(&atx, &b) > 1e-8 {
            return Err("transpose residual too large".into());
        }
        Ok(())
    });
}

#[test]
fn prop_cholesky_matches_lu_on_spd() {
    check("cholesky == lu on SPD", 15, |rng| {
        let n = 10 + rng.below(40);
        let per_row = 2 + rng.below(3);
        let shift = 1.0 + rng.uniform();
        let a = random_spd(rng, n, per_row, shift);
        let b = rng.normal_vec(n);
        let xc = EnvelopeCholesky::factor_rcm(&a)
            .map_err(|e| e.to_string())?
            .solve(&b);
        let xl = SparseLu::factor(&a)
            .map_err(|e| e.to_string())?
            .solve(&b)
            .map_err(|e| e.to_string())?;
        close(&xc, &xl, 1e-6)
    });
}

#[test]
fn prop_krylov_solvers_agree() {
    check("cg == bicgstab == gmres on SPD", 10, |rng| {
        let n = 20 + rng.below(40);
        let a = random_spd(rng, n, 3, 2.0);
        let b = rng.normal_vec(n);
        let opts = IterOpts {
            tol: 1e-11,
            max_iters: 50_000,
            record_history: false,
        };
        let m = Jacobi::new(&a).map_err(|e| e.to_string())?;
        let x1 = cg(&a, &b, &m, &opts, None);
        let x2 = bicgstab(&a, &b, &m, &opts, None);
        let x3 = gmres(&a, &b, &Identity, 40, &opts, None);
        if !(x1.converged && x2.converged && x3.converged) {
            return Err("not all converged".into());
        }
        close(&x1.x, &x2.x, 1e-6)?;
        close(&x1.x, &x3.x, 1e-6)
    });
}

#[test]
fn prop_adjoint_db_equals_transpose_solve() {
    // dL/db for L = <w, x> must equal A^{-T} w regardless of backend
    check("adjoint db identity", 10, |rng| {
        let n = 10 + rng.below(30);
        let a = random_nonsymmetric(rng, n, 3);
        let pattern = Pattern::of(&a);
        let b = rng.normal_vec(n);
        let w = rng.normal_vec(n);
        let solver = native_solver();
        let tape = Tape::new();
        let vals = tape.leaf_vec(a.vals.clone());
        let bv = tape.leaf_vec(b);
        let x = solve_linear(&tape, &pattern, vals, bv, &solver).map_err(|e| e.to_string())?;
        let wv = tape.constant_vec(w.clone());
        let loss = tape.dot(x, wv);
        let grads = tape.backward(loss);
        let want = (solver)(&pattern, &a.vals, &w, Transpose::Yes).map_err(|e| e.to_string())?;
        close(grads.vec(bv), &want, 1e-7)
    });
}

#[test]
fn prop_stencil_assembly_consistent() {
    // stencil spmv == csr spmv for random positive kappa
    check("stencil == csr", 15, |rng| {
        let g = 4 + rng.below(20);
        let kappa: Vec<f64> = (0..g * g).map(|_| 0.2 + rng.uniform() * 3.0).collect();
        let coeffs = stencil_coeffs(g, Some(&kappa));
        let a = coeffs.to_csr();
        let x = rng.normal_vec(g * g);
        let mut y = vec![0.0; g * g];
        coeffs.spmv(&x, &mut y);
        close(&y, &a.matvec(&x), 1e-9)
    });
}

#[test]
fn prop_dense_eigh_reconstructs() {
    check("jacobi_eigh A v = lambda v", 15, |rng| {
        let n = 3 + rng.below(12);
        let mut a = vec![0.0; n * n];
        for i in 0..n {
            for j in i..n {
                let v = rng.normal();
                a[i * n + j] = v;
                a[j * n + i] = v;
            }
        }
        let (vals, vecs) = jacobi_eigh(&a, n);
        for (lam, v) in vals.iter().zip(&vecs) {
            for i in 0..n {
                let av: f64 = (0..n).map(|j| a[i * n + j] * v[j]).sum();
                if (av - lam * v[i]).abs() > 1e-7 {
                    return Err(format!("residual at lambda={lam}"));
                }
            }
        }
        // ascending order
        for w in vals.windows(2) {
            if w[0] > w[1] + 1e-12 {
                return Err("not sorted".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_distributed_solve_matches_serial() {
    check("dist solve == serial", 6, |rng| {
        let g = 8 + rng.below(8);
        let nparts = 2 + rng.below(3);
        let sys = poisson2d(g, None);
        let strat = match rng.below(3) {
            0 => PartitionStrategy::Contiguous,
            1 => PartitionStrategy::Rcb,
            _ => PartitionStrategy::GreedyBfs,
        };
        let dt = DSparseTensor::from_global(&sys.matrix, Some(&sys.coords), nparts, strat)
            .map_err(|e| e.to_string())?;
        let b = rng.normal_vec(g * g);
        let (x, _) = dt
            .solve(
                &b,
                &DistIterOpts {
                    tol: 1e-11,
                    max_iters: 50_000,
                ..Default::default()
            },
            )
            .map_err(|e| e.to_string())?;
        let want = direct_solve(&sys.matrix, &b).map_err(|e| e.to_string())?;
        close(&x, &want, 1e-5)
    });
}

#[test]
fn prop_all_reduce_is_deterministic_sum() {
    check("all_reduce sum", 10, |rng| {
        let p = 2 + rng.below(5);
        let vals: Vec<f64> = (0..p).map(|_| rng.normal()).collect();
        let want: f64 = vals.iter().sum();
        let vals2 = vals.clone();
        let results = run_ranks(p, move |c| c.all_reduce_sum(vals2[c.rank()]));
        for r in results {
            if (r - want).abs() > 1e-12 * (1.0 + want.abs()) {
                return Err(format!("{r} vs {want}"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_graph_laplacian_kernel_is_constants() {
    // L * 1 = shift * 1 for every generated Laplacian
    check("laplacian null space", 15, |rng| {
        let n = 10 + rng.below(100);
        let shift = rng.uniform();
        let deg = 3 + rng.below(3);
        let l = random_graph_laplacian(rng, n, deg, shift);
        let ones = vec![1.0; n];
        let y = l.matvec(&ones);
        close(&y, &vec![shift; n], 1e-9)
    });
}

#[test]
fn prop_tape_grad_accumulation_linear() {
    // gradient of a*L1 + b*L2 == a*grad(L1) + b*grad(L2)
    check("tape linearity", 10, |rng| {
        let n = 5 + rng.below(20);
        let x0 = rng.normal_vec(n);
        let (ca, cb) = (rng.normal(), rng.normal());
        let grad_of = |wa: f64, wb: f64| -> Vec<f64> {
            let t = Tape::new();
            let x = t.leaf_vec(x0.clone());
            let l1 = t.dot(x, x);
            let sq = t.mul(x, x);
            let l2 = t.sum(sq);
            let s1 = t.scale_const_s(wa, l1);
            let s2 = t.scale_const_s(wb, l2);
            let loss = t.add_ss(s1, s2);
            t.backward(loss).vec(x).clone()
        };
        let g_both = grad_of(ca, cb);
        let g_a = grad_of(ca, 0.0);
        let g_b = grad_of(0.0, cb);
        let combined: Vec<f64> = g_a.iter().zip(&g_b).map(|(p, q)| p + q).collect();
        close(&g_both, &combined, 1e-10)
    });
}

#[test]
fn prop_slogdet_matches_dense_2x2_blocks() {
    // random block-diagonal 2x2 matrices have analytic determinants
    check("slogdet block diagonal", 15, |rng| {
        let blocks = 1 + rng.below(10);
        let n = 2 * blocks;
        let mut coo = Coo::new(n, n);
        let mut det = 1.0f64;
        for b in 0..blocks {
            let (i, j) = (2 * b, 2 * b + 1);
            let (a11, a12, a21, a22) = (
                rng.normal() + 3.0,
                rng.normal(),
                rng.normal(),
                rng.normal() + 3.0,
            );
            coo.push(i, i, a11);
            coo.push(i, j, a12);
            coo.push(j, i, a21);
            coo.push(j, j, a22);
            det *= a11 * a22 - a12 * a21;
        }
        let f = SparseLu::factor(&coo.to_csr()).map_err(|e| e.to_string())?;
        let (sign, logabs) = f.slogdet();
        let got = sign * logabs.exp();
        if (got - det).abs() > 1e-6 * (1.0 + det.abs()) {
            return Err(format!("{got} vs {det}"));
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Properties over the extension features: MINRES, IC(0), AMG, pipelined
// CG, eigenvector adjoints.
// ---------------------------------------------------------------------

#[test]
fn prop_minres_agrees_with_cg_on_spd() {
    // on SPD systems MINRES and CG must find the same solution
    check("minres == cg on SPD", 10, |rng| {
        let n = 12 + rng.below(40);
        let a = random_spd(rng, n, 3, 1.0);
        let b = rng.normal_vec(n);
        let opts = IterOpts {
            tol: 1e-11,
            max_iters: 50_000,
            record_history: false,
        };
        let r1 = cg(&a, &b, &Identity, &opts, None);
        let r2 = rsla::iterative::minres(&a, &b, &Identity, &opts, None);
        if !r1.converged || !r2.converged {
            return Err(format!(
                "not converged: cg {} minres {}",
                r1.residual, r2.residual
            ));
        }
        close(&r1.x, &r2.x, 1e-6)
    });
}

#[test]
fn prop_ic0_is_spd_preserving_preconditioner() {
    // z = M^{-1} r from IC(0) must satisfy <x, M^{-1} y> == <M^{-1} x, y>
    // and accelerate CG on random SPD systems
    check("ic0 symmetric + accelerates", 10, |rng| {
        let g = 8 + rng.below(16);
        let kappa: Vec<f64> = (0..g * g).map(|_| 0.2 + rng.uniform() * 3.0).collect();
        let sys = poisson2d(g, Some(&kappa));
        let ic = rsla::iterative::Ic0::new(&sys.matrix).map_err(|e| e.to_string())?;
        let n = g * g;
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let mut mx = vec![0.0; n];
        let mut my = vec![0.0; n];
        use rsla::iterative::Precond;
        ic.apply(&x, &mut mx);
        ic.apply(&y, &mut my);
        let lhs = dot(&x, &my);
        let rhs = dot(&mx, &y);
        if (lhs - rhs).abs() > 1e-8 * lhs.abs().max(rhs.abs()).max(1.0) {
            return Err(format!("IC0 not symmetric: {lhs} vs {rhs}"));
        }
        let opts = IterOpts {
            tol: 1e-9,
            max_iters: 50_000,
            record_history: false,
        };
        let plain = cg(&sys.matrix, &x, &Identity, &opts, None);
        let pre = cg(&sys.matrix, &x, &ic, &opts, None);
        if !pre.converged {
            return Err("IC0-CG did not converge".into());
        }
        if pre.iters > plain.iters {
            return Err(format!("IC0 slower: {} vs {}", pre.iters, plain.iters));
        }
        Ok(())
    });
}

#[test]
fn prop_amg_vcycle_contracts_error() {
    // one V-cycle must strictly reduce the A-norm error of a random
    // initial guess on Poisson-like systems
    check("amg v-cycle contracts", 8, |rng| {
        let g = 12 + rng.below(24);
        let kappa: Vec<f64> = (0..g * g).map(|_| 0.5 + rng.uniform() * 2.0).collect();
        let sys = poisson2d(g, Some(&kappa));
        let amg = rsla::iterative::Amg::new(&sys.matrix, &rsla::iterative::AmgOpts::default())
            .map_err(|e| e.to_string())?;
        let n = g * g;
        // error equation: A e = r with random r
        let r = rng.normal_vec(n);
        use rsla::iterative::Precond;
        let mut z = vec![0.0; n];
        amg.apply(&r, &mut z);
        // residual after the cycle: ||r - A z|| must be < ||r||
        let az = sys.matrix.matvec(&z);
        let before = util::norm2(&r);
        let after = util::norm2(
            &r.iter()
                .zip(&az)
                .map(|(a, b)| a - b)
                .collect::<Vec<f64>>(),
        );
        if after >= before {
            return Err(format!("V-cycle did not contract: {before} -> {after}"));
        }
        Ok(())
    });
}

#[test]
fn prop_pipelined_cg_equals_standard_cg() {
    // the single-reduction recurrence is algebraically the same Krylov
    // method: solutions must agree on random SPD systems
    check("pipelined == standard dist CG", 6, |rng| {
        let g = 10 + rng.below(14);
        let n = g * g;
        let kappa: Vec<f64> = (0..n).map(|_| 0.3 + rng.uniform() * 2.0).collect();
        let sys = poisson2d(g, Some(&kappa));
        let nparts = 2 + rng.below(3) as usize;
        let dt = DSparseTensor::from_global(
            &sys.matrix,
            Some(&sys.coords),
            nparts,
            PartitionStrategy::Contiguous,
        )
        .map_err(|e| e.to_string())?;
        let b = rng.normal_vec(n);
        let opts = DistIterOpts {
            tol: 1e-11,
            max_iters: 50_000,
            ..Default::default()
        };
        let (x_std, _) = dt.solve(&b, &opts).map_err(|e| e.to_string())?;
        // pipelined via raw rank API
        use rsla::distributed::dist_cg_pipelined;
        use std::sync::Arc;
        let part = dt.partition();
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let shares = Arc::new(rsla::distributed::halo::distribute(&a_perm, part));
        let mut b_perm = vec![0.0; n];
        for i in 0..n {
            b_perm[i] = b[part.perm[i]];
        }
        let b_perm = Arc::new(b_perm);
        let offsets: Vec<std::ops::Range<usize>> =
            (0..nparts).map(|p| part.rank_range(p)).collect();
        let o2 = offsets.clone();
        let opts2 = opts.clone();
        let reports = run_ranks(nparts, move |c| {
            let p = c.rank();
            dist_cg_pipelined(&shares[p], &b_perm[o2[p].clone()], &c, &opts2)
        });
        let mut x_pip = vec![0.0; n];
        let mut idx = 0;
        for r in &reports {
            for v in &r.x_own {
                // un-permute: new index idx holds old row perm[idx]
                x_pip[part.perm[idx]] = *v;
                idx += 1;
            }
        }
        close(&x_pip, &x_std, 1e-6)
    });
}

#[test]
fn prop_eigsh_vector_gradient_scaling_invariance() {
    // eigenvectors are invariant under A -> (1+t) A, so the directional
    // derivative of any eigenvector-only loss along E = A must vanish:
    // sum_k dvals_k * A_k ~ 0.  (This direction IS representable on the
    // sparsity pattern, unlike a dense rank-1 probe.)
    check("eigsh vector grad scaling invariance", 5, |rng| {
        let a = random_graph_laplacian(rng, 24, 4, 0.5);
        let pattern = Pattern::of(&a);
        let tape = Tape::new();
        let vals = tape.leaf_vec(a.vals.clone());
        let opts = rsla::eigen::LobpcgOpts {
            tol: 1e-12,
            max_iters: 3000,
            seed: 9,
        };
        let (_l, vecs, res) = rsla::adjoint::eigsh_with_vectors(&tape, &pattern, vals, 2, &opts)
            .map_err(|e| e.to_string())?;
        let u = rng.normal_vec(24);
        let uv = tape.constant_vec(u);
        let s = tape.dot(vecs[1], uv);
        let loss = tape.mul_ss(s, s);
        let grads = tape.backward(loss);
        let dvals = grads.vec(vals).clone();
        let _ = &res;
        // <dL/dA, A> on the pattern = d/dt L((1+t)A) at t=0 = 0
        let q = dot(&dvals, &a.vals);
        let scale = util::norm2(&dvals) * util::norm2(&a.vals);
        if q.abs() > 1e-6 * (1.0 + scale) {
            return Err(format!(
                "<dA, A> = {q} (should vanish; scale {scale:.3e})"
            ));
        }
        Ok(())
    });
}
