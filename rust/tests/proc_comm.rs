//! End-to-end suite for the PROCESS rank-team backend (`ProcComm`):
//! a 4-rank solve over real worker processes must be bitwise-identical
//! to the in-process `LocalComm` solve (the canonical rank-ascending
//! reduction order at work), report identical algorithmic round counts,
//! and a rank dying mid-solve must surface as a typed
//! [`rsla::Error::RankDead`] through the engine — never a hang.

use std::sync::Arc;

use rsla::backend::Dispatcher;
use rsla::distributed::{
    CommBackend, DSparseTensor, DistIterOpts, DistMethod, PartitionStrategy, ProcOpts,
    TransportKind,
};
use rsla::engine::{Engine, EngineConfig, JobSpec};
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::util::Prng;
use rsla::Error;

/// Worker re-exec target: spawned rank-team children run this test
/// binary as `proc_comm proc_worker_entry --exact`, which lands here
/// and hands control to the worker protocol (the call exits the
/// process when the worker env is present, and is a no-op for a normal
/// test run).
#[test]
fn proc_worker_entry() {
    rsla::distributed::maybe_run_worker();
}

fn problem(g: usize) -> (DSparseTensor, Vec<f64>) {
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let t = DSparseTensor::from_global(&sys.matrix, Some(&sys.coords), 4, PartitionStrategy::Rcb)
        .expect("partition");
    let mut rng = Prng::new(g as u64);
    let b = rng.normal_vec(g * g);
    (t, b)
}

fn opts_with(method: DistMethod, backend: CommBackend) -> DistIterOpts {
    DistIterOpts {
        tol: 1e-9,
        method,
        backend,
        ..Default::default()
    }
}

/// Acceptance pin: the 4-rank process-backend solve is bitwise
/// identical to the thread-backend solve for standard CG, on both the
/// shared-memory and the socket transport, with identical algorithmic
/// accounting (iterations, reduction rounds, bytes sent per rank).
#[test]
fn four_rank_proc_solve_is_bitwise_identical_to_local() {
    let (t, b) = problem(24);
    let (x_local, rep_local) = t
        .solve(&b, &opts_with(DistMethod::Cg, CommBackend::Local))
        .expect("local solve");

    for kind in [TransportKind::Shm, TransportKind::Socket] {
        let popts = ProcOpts::for_tests(kind);
        let (x_proc, rep_proc) = t
            .solve(&b, &opts_with(DistMethod::Cg, CommBackend::Proc(popts)))
            .expect("proc solve");
        assert_eq!(rep_proc.len(), 4);
        for (l, p) in rep_local.iter().zip(&rep_proc) {
            assert_eq!(l.iters, p.iters, "{kind:?}: iteration counts diverged");
            assert_eq!(
                l.reduce_rounds, p.reduce_rounds,
                "{kind:?}: ProcComm and LocalComm must report identical round counts"
            );
            assert_eq!(
                l.bytes_sent, p.bytes_sent,
                "{kind:?}: algorithmic halo-byte accounting diverged"
            );
        }
        assert_eq!(x_local.len(), x_proc.len());
        for (i, (l, p)) in x_local.iter().zip(&x_proc).enumerate() {
            assert_eq!(
                l.to_bits(),
                p.to_bits(),
                "{kind:?}: x[{i}] differs: local {l:e} vs proc {p:e}"
            );
        }
        // physical transport stats exist only on the process backend
        assert!(
            rep_proc.iter().all(|r| r.transport.wire_msgs > 0),
            "{kind:?}: proc ranks must report wire traffic"
        );
        assert!(
            rep_local.iter().all(|r| r.transport.wire_msgs == 0),
            "thread ranks must report zero wire traffic"
        );
    }
}

/// CA-CG rides the same transport: identical rounds and bitwise-equal
/// solutions across backends for the s-step kernel too.
#[test]
fn four_rank_proc_ca_cg_matches_local() {
    let (t, b) = problem(24);
    let method = DistMethod::CaCg { s: 4 };
    let (x_local, rep_local) = t
        .solve(&b, &opts_with(method.clone(), CommBackend::Local))
        .expect("local solve");
    let popts = ProcOpts::for_tests(TransportKind::Shm);
    let (x_proc, rep_proc) = t
        .solve(&b, &opts_with(method, CommBackend::Proc(popts)))
        .expect("proc solve");
    assert_eq!(rep_local[0].iters, rep_proc[0].iters);
    assert_eq!(rep_local[0].reduce_rounds, rep_proc[0].reduce_rounds);
    assert!(rep_proc.iter().all(|r| r.converged));
    for (l, p) in x_local.iter().zip(&x_proc) {
        assert_eq!(l.to_bits(), p.to_bits());
    }
}

/// A worker killed after receiving its job (the `fail_rank` hook makes
/// rank 2 exit before solving) must surface as `Error::RankDead` from
/// `DSparseTensor::solve` within the team timeout — a typed error, not
/// a hang, and naming the dead rank.
#[test]
fn dead_rank_surfaces_typed_error_not_hang() {
    let (t, b) = problem(16);
    let popts = ProcOpts {
        fail_rank: Some(2),
        timeout_ms: 60_000,
        ..ProcOpts::for_tests(TransportKind::Shm)
    };
    let err = t
        .solve(&b, &opts_with(DistMethod::Cg, CommBackend::Proc(popts)))
        .expect_err("a dead rank must fail the solve");
    match err {
        Error::RankDead { rank, ref detail } => {
            assert_eq!(rank, 2, "wrong rank blamed: {detail}");
        }
        other => panic!("expected RankDead, got: {other}"),
    }
}

/// Same failure through the engine: `JobKind::Dist` launches the
/// process team, monitors liveness, and the dead rank flows to the
/// job ticket as a typed error while the engine stays serviceable.
#[test]
fn engine_dist_job_reports_dead_rank_as_typed_error() {
    let e = Engine::start(Arc::new(Dispatcher::new(None)), EngineConfig::default());
    let (t, b) = problem(16);
    let opts = opts_with(
        DistMethod::Cg,
        CommBackend::Proc(ProcOpts {
            fail_rank: Some(1),
            timeout_ms: 60_000,
            ..ProcOpts::for_tests(TransportKind::Shm)
        }),
    );
    let r = e
        .submit(JobSpec::Dist {
            tensor: t,
            b,
            opts,
        })
        .expect("submit")
        .wait();
    match r.outcome {
        Err(Error::RankDead { rank, .. }) => assert_eq!(rank, 1),
        Err(other) => panic!("expected RankDead, got: {other}"),
        Ok(_) => panic!("dead rank must not produce a successful solve"),
    }

    // the engine survives the failed team: a healthy solve still works
    let (t2, b2) = problem(12);
    let r2 = e
        .submit(JobSpec::Dist {
            tensor: t2,
            b: b2,
            opts: DistIterOpts::default(),
        })
        .expect("submit")
        .wait();
    assert!(r2.outcome.is_ok(), "engine must stay serviceable");
    e.shutdown();
}
