//! Paper Fig. 3: inverse coefficient learning, bench form.
//!
//! Runs the 64x64 variable-coefficient Poisson inverse problem for a
//! fixed 300-step budget (the full 1500-step run lives in
//! `examples/inverse_coefficient.rs`) and reports the loss / error
//! series the figure plots, plus per-step timing split into
//! assembly/forward/backward/optimizer phases.
//!
//! Run: cargo bench --bench fig3_inverse

use rsla::autograd::Tape;
use rsla::backend::SolveOpts;
use rsla::optim::Adam;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::tensor::PoissonAssembler;
use rsla::util;

fn main() {
    let steps = 300;
    let g = 64;
    let n = g * g;
    let asm = PoissonAssembler::new(g);
    let kappa_true = kappa_star(g);
    let sys_true = poisson2d(g, Some(&kappa_true));
    let f_rhs = vec![1.0; n];
    let u_obs = rsla::direct::direct_solve(&sys_true.matrix, &f_rhs).unwrap();

    let theta0 = (1.0f64.exp() - 1.0).ln();
    let mut theta = vec![theta0; n];
    let mut adam = Adam::new(n, 5e-2);
    let solver = rsla::tensor::SparseTensor::from_csr(sys_true.matrix.clone()).solver_fn(SolveOpts {
        tol: 1e-11,
        ..Default::default()
    });

    println!("# Fig 3 (300-step bench): loss + rel-L2(kappa) series (paper: both monotone)");
    println!("| {:>5} | {:>12} | {:>12} | {:>12} |", "step", "loss", "k rel-L2", "u rel-L2");
    println!("|-------|--------------|--------------|--------------|");

    let mut t_fwd = 0.0;
    let mut t_bwd = 0.0;
    let mut t_opt = 0.0;
    let mut last_err = f64::NAN;
    let mut prev_loss = f64::INFINITY;
    let mut monotone_violations = 0;
    let t_total = std::time::Instant::now();
    for step in 0..steps {
        let t0 = std::time::Instant::now();
        let tape = Tape::new();
        let th = tape.leaf_vec(theta.clone());
        let kappa = tape.softplus(th);
        let vals = asm.assemble(&tape, kappa);
        let b = tape.constant_vec(f_rhs.clone());
        let u = rsla::adjoint::solve_linear(&tape, &asm.pattern, vals, b, &solver).unwrap();
        let uo = tape.constant_vec(u_obs.clone());
        let diff = tape.sub(u, uo);
        let data = tape.dot(diff, diff);
        let reg = asm.smoothness(&tape, kappa);
        let reg_s = tape.scale_const_s(1e-3, reg);
        let loss = tape.add_ss(data, reg_s);
        t_fwd += t0.elapsed().as_secs_f64();

        let t1 = std::time::Instant::now();
        let grads = tape.backward(loss);
        t_bwd += t1.elapsed().as_secs_f64();

        let t2 = std::time::Instant::now();
        adam.step(&mut theta, grads.vec(th));
        t_opt += t2.elapsed().as_secs_f64();

        let l = tape.scalar_of(loss);
        if l > prev_loss * 1.5 {
            monotone_violations += 1;
        }
        prev_loss = l;
        if step % 50 == 0 || step + 1 == steps {
            let kv = tape.vec_of(kappa);
            last_err = util::rel_l2(&kv, &kappa_true);
            let ue = util::rel_l2(&tape.vec_of(u), &u_obs);
            println!("| {step:>5} | {l:>12.4e} | {last_err:>12.3e} | {ue:>12.3e} |");
        }
    }
    let total = t_total.elapsed().as_secs_f64();
    println!();
    println!(
        "{} steps in {:.1} s = {:.1} ms/step (paper: 32 ms/step on RTX PRO 6000)",
        steps,
        total,
        total * 1e3 / steps as f64
    );
    println!(
        "phase split: fwd(assembly+solve) {:.1} ms  bwd(adjoint) {:.1} ms  adam {:.2} ms",
        t_fwd * 1e3 / steps as f64,
        t_bwd * 1e3 / steps as f64,
        t_opt * 1e3 / steps as f64
    );
    println!("kappa rel-L2 after {steps} steps: {last_err:.3e} (full 1500-step run: 1.4e-3; paper 2.3e-3)");
    assert!(last_err < 0.15, "not converging: {last_err}");
    assert!(monotone_violations <= steps / 20, "loss not near-monotone");
}
