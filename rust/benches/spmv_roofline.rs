//! SpMV roofline benchmark: measured bandwidth per format (CSR vs
//! SELL-C-σ) across row-length distributions, reported as a fraction of
//! the machine's streaming bandwidth (a STREAM-triad probe run in the
//! same process), plus the fused multi-RHS win.
//!
//! What it asserts — measurements, not theory:
//!
//! * the fused k=8 block SpMV beats 8 separate CSR passes by >= 1.5x on
//!   the large Poisson operator (one read of `vals`/`indices` instead
//!   of 8, the whole point of `kernels::spmv_block`);
//! * SELL-C-σ out-runs CSR on at least one benched distribution (the
//!   short-row regimes the cost model routes to it);
//! * the cost model's choice agrees with the measured winner on the
//!   clear-cut distributions (regular -> SELL, power-law -> CSR).
//!
//! Emits `BENCH_spmv.json` (GB/s, roofline fraction, occupancy and the
//! model's choice per distribution x size; fused vs unfused k-RHS) for
//! the CI perf trajectory.  Thresholds and the bytes-moved accounting
//! are documented in `docs/kernels.md#roofline-bench`.
//!
//! Run: cargo bench --bench spmv_roofline

use std::time::Instant;

use rsla::sparse::kernels::spmv_block;
use rsla::sparse::poisson::poisson2d;
use rsla::sparse::sell::{DEFAULT_CHUNK, DEFAULT_SIGMA};
use rsla::sparse::{choose_format, Csr, FormatChoice, Sell};
use rsla::util::Prng;

/// Wall-clock floor per measurement; keeps timer noise out of GB/s.
const MIN_MEASURE_S: f64 = 0.15;

/// Useful bytes one SpMV must move, the roofline numerator shared by
/// both formats: every stored entry's value + index, the dense x and y
/// vectors once each, and the row-offset stream.  Padding and format
/// overhead are deliberately NOT counted — they show up as a LOWER
/// achieved fraction, which is exactly the comparison the cost model
/// makes.
fn spmv_bytes(a: &Csr) -> f64 {
    (a.nnz() * 16 + (a.nrows + a.ncols) * 8 + (a.nrows + 1) * 8) as f64
}

/// Time `f` with enough repetitions to fill the measurement floor;
/// returns best-of-3 seconds per call (min filters scheduler noise).
fn time_per_call<F: FnMut()>(mut f: F) -> f64 {
    // calibrate
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let reps = ((MIN_MEASURE_S / once).ceil() as usize).clamp(1, 1_000_000);
    let mut best = f64::INFINITY;
    for _ in 0..3 {
        let t0 = Instant::now();
        for _ in 0..reps {
            f();
        }
        best = best.min(t0.elapsed().as_secs_f64() / reps as f64);
    }
    best
}

/// STREAM-style triad (`a[i] = b[i] + s * c[i]`) over arrays far larger
/// than cache: the machine bandwidth the roofline fractions divide by.
fn stream_bandwidth_gbs() -> f64 {
    let n = 8_000_000usize; // 3 x 64 MB streams
    let b = vec![1.0f64; n];
    let c = vec![2.0f64; n];
    let mut a = vec![0.0f64; n];
    let s = 1.5f64;
    let secs = time_per_call(|| {
        for ((ai, bi), ci) in a.iter_mut().zip(&b).zip(&c) {
            *ai = bi + s * ci;
        }
        std::hint::black_box(&a);
    });
    (n * 3 * 8) as f64 / secs / 1e9
}

fn banded(n: usize, per_row: usize) -> Csr {
    let mut indptr = vec![0usize];
    let mut indices = Vec::new();
    let mut vals = Vec::new();
    for r in 0..n {
        // stride-37 diagonals: distinct columns as long as 37*per_row < n
        let mut cols: Vec<usize> = (0..per_row).map(|d| (r + d * 37) % n).collect();
        cols.sort_unstable();
        for (d, c) in cols.into_iter().enumerate() {
            indices.push(c);
            vals.push(1.0 + d as f64);
        }
        indptr.push(indices.len());
    }
    Csr {
        nrows: n,
        ncols: n,
        indptr,
        indices,
        vals,
    }
    .debug_validate()
}

fn power_law(rng: &mut Prng, n: usize) -> Csr {
    let mut indptr = vec![0usize];
    let mut indices = Vec::new();
    let mut vals = Vec::new();
    for r in 0..n {
        let len = if r % 211 == 0 { 1500.min(n) } else { 1 + r % 3 };
        let mut cols = rng.choose_distinct(n, len);
        cols.sort_unstable();
        for c in cols {
            indices.push(c);
            vals.push(rng.normal());
        }
        indptr.push(indices.len());
    }
    Csr {
        nrows: n,
        ncols: n,
        indptr,
        indices,
        vals,
    }
    .debug_validate()
}

struct FormatRow {
    dist: String,
    nrows: usize,
    nnz: usize,
    choice: &'static str,
    occupancy: f64,
    csr_gbs: f64,
    sell_gbs: f64,
    csr_frac: f64,
    sell_frac: f64,
}

fn bench_formats(dist: &str, a: &Csr, stream_gbs: f64) -> FormatRow {
    let report = choose_format(a);
    let sell = Sell::from_csr(a, DEFAULT_CHUNK, DEFAULT_SIGMA);
    let mut rng = Prng::new(17);
    let x = rng.normal_vec(a.ncols);
    let mut y = vec![0.0; a.nrows];
    let bytes = spmv_bytes(a);

    let csr_secs = time_per_call(|| {
        a.spmv(&x, &mut y);
        std::hint::black_box(&y);
    });
    let sell_secs = time_per_call(|| {
        sell.spmv(&x, &mut y);
        std::hint::black_box(&y);
    });
    let (csr_gbs, sell_gbs) = (bytes / csr_secs / 1e9, bytes / sell_secs / 1e9);
    FormatRow {
        dist: dist.to_string(),
        nrows: a.nrows,
        nnz: a.nnz(),
        choice: report.choice.name(),
        occupancy: report.occupancy,
        csr_gbs,
        sell_gbs,
        csr_frac: csr_gbs / stream_gbs,
        sell_frac: sell_gbs / stream_gbs,
    }
}

struct FusedRow {
    dist: String,
    k: usize,
    fused_gbs: f64,
    unfused_gbs: f64,
    speedup: f64,
}

fn bench_fused(dist: &str, a: &Csr, k: usize) -> FusedRow {
    let mut rng = Prng::new(23);
    let cols: Vec<Vec<f64>> = (0..k).map(|_| rng.normal_vec(a.ncols)).collect();
    let mut xb = vec![0.0; a.ncols * k];
    for (j, c) in cols.iter().enumerate() {
        for (i, v) in c.iter().enumerate() {
            xb[i * k + j] = *v;
        }
    }
    let mut yb = vec![0.0; a.nrows * k];
    let fused_secs = time_per_call(|| {
        spmv_block(a, &xb, &mut yb, k);
        std::hint::black_box(&yb);
    });
    let mut ys: Vec<Vec<f64>> = (0..k).map(|_| vec![0.0; a.nrows]).collect();
    let unfused_secs = time_per_call(|| {
        for (c, y) in cols.iter().zip(ys.iter_mut()) {
            a.spmv(c, y);
        }
        std::hint::black_box(&ys);
    });
    // bytes a k-RHS product must move if the matrix is read ONCE
    let bytes = (a.nnz() * 16 + (a.nrows + 1) * 8 + (a.nrows + a.ncols) * 8 * k) as f64;
    FusedRow {
        dist: dist.to_string(),
        k,
        fused_gbs: bytes / fused_secs / 1e9,
        unfused_gbs: bytes / unfused_secs / 1e9,
        speedup: unfused_secs / fused_secs,
    }
}

fn main() {
    println!("# spmv_roofline: CSR vs SELL-C-sigma vs fused k-RHS");
    let stream_gbs = stream_bandwidth_gbs();
    println!("stream triad: {stream_gbs:.1} GB/s (roofline denominator)");

    let mut rng = Prng::new(3);
    let matrices: Vec<(String, Csr)> = vec![
        ("poisson2d_256".into(), poisson2d(256, None).matrix),
        ("poisson2d_768".into(), poisson2d(768, None).matrix),
        ("banded_short3".into(), banded(400_000, 3)),
        ("banded_wide16".into(), banded(150_000, 16)),
        ("power_law".into(), power_law(&mut rng, 120_000)),
    ];

    let format_rows: Vec<FormatRow> = matrices
        .iter()
        .map(|(d, a)| bench_formats(d, a, stream_gbs))
        .collect();
    for r in &format_rows {
        println!(
            "{:>14}: n={:<7} nnz={:<8} occ {:.2} model={:<4} csr {:6.2} GB/s ({:4.1}% roof)  sell {:6.2} GB/s ({:4.1}% roof)",
            r.dist,
            r.nrows,
            r.nnz,
            r.occupancy,
            r.choice,
            r.csr_gbs,
            100.0 * r.csr_frac,
            r.sell_gbs,
            100.0 * r.sell_frac,
        );
    }

    let fused_rows: Vec<FusedRow> = matrices
        .iter()
        .filter(|(d, _)| d.starts_with("poisson"))
        .flat_map(|(d, a)| [bench_fused(d, a, 4), bench_fused(d, a, 8)])
        .collect();
    for r in &fused_rows {
        println!(
            "{:>14}: k={} fused {:6.2} GB/s vs {} passes {:6.2} GB/s -> {:.2}x",
            r.dist, r.k, r.fused_gbs, r.k, r.unfused_gbs, r.speedup
        );
    }

    // acceptance: the fused win is measured on the large Poisson operator
    let big_fused = fused_rows
        .iter()
        .find(|r| r.dist == "poisson2d_768" && r.k == 8)
        .expect("poisson2d_768 k=8 row");
    assert!(
        big_fused.speedup >= 1.5,
        "fused k=8 block SpMV must beat 8 CSR passes by >= 1.5x on poisson2d_768 (got {:.2}x)",
        big_fused.speedup
    );
    // acceptance: SELL wins somewhere (the short-row regime exists)
    let sell_wins: Vec<&str> = format_rows
        .iter()
        .filter(|r| r.sell_gbs > r.csr_gbs)
        .map(|r| r.dist.as_str())
        .collect();
    assert!(
        !sell_wins.is_empty(),
        "SELL-C-sigma must beat CSR on at least one benched distribution"
    );
    println!("sell wins on: {}", sell_wins.join(", "));
    // sanity: the model's clear-cut calls match its own occupancy math
    let pl = format_rows
        .iter()
        .find(|r| r.dist == "power_law")
        .expect("power_law row");
    assert_eq!(pl.choice, FormatChoice::Csr.name(), "power-law must stay CSR");
    for r in format_rows.iter().filter(|r| r.dist.starts_with("poisson")) {
        assert_eq!(r.choice, FormatChoice::Sell.name(), "{} must pick SELL", r.dist);
    }

    // machine-readable trajectory for CI
    let mut json = String::from("{\n  \"bench\": \"spmv_roofline\",\n");
    json.push_str(&format!("  \"stream_gbs\": {stream_gbs:.2},\n"));
    json.push_str("  \"formats\": [\n");
    for (i, r) in format_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"dist\": \"{}\", \"nrows\": {}, \"nnz\": {}, \"occupancy\": {:.4}, \"model_choice\": \"{}\", \"csr_gbs\": {:.3}, \"sell_gbs\": {:.3}, \"csr_roofline_frac\": {:.4}, \"sell_roofline_frac\": {:.4}}}{}\n",
            r.dist,
            r.nrows,
            r.nnz,
            r.occupancy,
            r.choice,
            r.csr_gbs,
            r.sell_gbs,
            r.csr_frac,
            r.sell_frac,
            if i + 1 == format_rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ],\n  \"fused\": [\n");
    for (i, r) in fused_rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"dist\": \"{}\", \"k\": {}, \"fused_gbs\": {:.3}, \"unfused_gbs\": {:.3}, \"speedup\": {:.3}}}{}\n",
            r.dist,
            r.k,
            r.fused_gbs,
            r.unfused_gbs,
            r.speedup,
            if i + 1 == fused_rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_spmv.json", &json).expect("write BENCH_spmv.json");
    println!("\nwrote BENCH_spmv.json ({} distributions, stream {stream_gbs:.1} GB/s)", format_rows.len());
}
