//! Distributed scaling microbenchmark: standard (two-reduction) vs
//! pipelined (single-reduction) vs s-step communication-avoiding CG at
//! 1/2/4 ranks, reporting the communication structure the paper's
//! Algorithm 1 / Appendix C pin: iterations, reduction ROUNDS (latency
//! units — the quantity pipelining halves and CA-CG divides by ~s),
//! and bytes sent per iteration (halo volume — identical across
//! variants, since only the reductions are reorganized).
//!
//! Also runs the same solves over the PROCESS transport (`ProcComm`,
//! shared-memory rings) and asserts backend equivalence: identical
//! round counts and a bitwise-identical solution — the canonical
//! rank-ascending reduction order at work — plus a weak-scaling sweep
//! (fixed rows per rank).
//!
//! Emits `BENCH_dist.json` next to the working directory so CI archives
//! a machine-readable perf trajectory.
//!
//! Run: cargo bench --bench dist_scaling

use std::sync::Arc;
use std::time::Instant;

use rsla::distributed::halo::distribute;
use rsla::distributed::partition::{partition, PartitionStrategy};
use rsla::distributed::{
    dist_cg, dist_cg_ca, dist_cg_pipelined, maybe_run_worker, run_ranks, CommBackend,
    DSparseTensor, DistIterOpts, DistMethod, DistSolveReport, ProcOpts, TransportKind,
};
use rsla::krylov::CaCgOpts;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::util::Prng;

#[derive(Clone, Copy, PartialEq)]
enum Variant {
    Standard,
    Pipelined,
    Ca(usize),
}

impl Variant {
    fn name(self) -> String {
        match self {
            Variant::Standard => "standard".into(),
            Variant::Pipelined => "pipelined".into(),
            Variant::Ca(s) => format!("ca-s{s}"),
        }
    }
}

struct Row {
    variant: String,
    ranks: usize,
    n: usize,
    iters: usize,
    reduce_rounds: u64,
    rounds_per_iter: f64,
    bytes_per_iter_per_rank: f64,
    wall_ms: f64,
    converged: bool,
}

fn measure(g: usize, nparts: usize, variant: Variant) -> (Vec<DistSolveReport>, f64) {
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let part = partition(&sys.matrix, Some(&sys.coords), nparts, PartitionStrategy::Rcb);
    let a_perm = sys.matrix.permute_sym(&part.perm);
    let shares = Arc::new(distribute(&a_perm, &part));
    let mut rng = Prng::new(g as u64);
    let b = Arc::new(rng.normal_vec(g * g));
    let part = Arc::new(part);
    let t0 = Instant::now();
    let reports = run_ranks(nparts, move |c| {
        let p = c.rank();
        let range = part.rank_range(p);
        let opts = DistIterOpts {
            tol: 1e-9,
            ..Default::default()
        };
        match variant {
            Variant::Standard => dist_cg(&shares[p], &b[range], &c, &opts),
            Variant::Pipelined => dist_cg_pipelined(&shares[p], &b[range], &c, &opts),
            Variant::Ca(s) => {
                let ca = CaCgOpts {
                    s,
                    ..Default::default()
                };
                dist_cg_ca(&shares[p], &b[range], &c, &opts, &ca)
            }
        }
    });
    (reports, t0.elapsed().as_secs_f64())
}

fn row_of(
    variant: &Variant,
    ranks: usize,
    n: usize,
    reports: &[DistSolveReport],
    secs: f64,
) -> Row {
    let iters = reports[0].iters.max(1);
    let rounds = reports[0].reduce_rounds;
    let max_sent = reports.iter().map(|r| r.bytes_sent).max().unwrap();
    Row {
        variant: variant.name(),
        ranks,
        n,
        iters: reports[0].iters,
        reduce_rounds: rounds,
        rounds_per_iter: rounds as f64 / iters as f64,
        bytes_per_iter_per_rank: max_sent as f64 / iters as f64,
        wall_ms: secs * 1e3,
        converged: reports.iter().all(|r| r.converged),
    }
}

fn print_row(row: &Row) {
    println!(
        "| {:>9} | {:>5} | {:>6} | {:>7} | {:>11.2} | {:>12.2} | {:>6.1} ms |",
        row.variant,
        row.ranks,
        row.iters,
        row.reduce_rounds,
        row.rounds_per_iter,
        row.bytes_per_iter_per_rank / 1e3,
        row.wall_ms,
    );
}

/// Same solve, thread backend vs process backend: round counts must be
/// identical and the solution bitwise equal (canonical reduction order).
fn backend_parity(g: usize, ranks: usize, method: DistMethod) -> (Row, Row) {
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let t =
        DSparseTensor::from_global(&sys.matrix, Some(&sys.coords), ranks, PartitionStrategy::Rcb)
            .expect("partition");
    let mut rng = Prng::new(g as u64);
    let b = rng.normal_vec(g * g);
    let mk_opts = |backend| DistIterOpts {
        tol: 1e-9,
        method: method.clone(),
        backend,
        ..Default::default()
    };
    let t0 = Instant::now();
    let (x_local, rep_local) = t.solve(&b, &mk_opts(CommBackend::Local)).expect("local solve");
    let local_secs = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let (x_proc, rep_proc) = t
        .solve(
            &b,
            &mk_opts(CommBackend::Proc(ProcOpts {
                kind: TransportKind::Shm,
                ..ProcOpts::default()
            })),
        )
        .expect("proc solve");
    let proc_secs = t1.elapsed().as_secs_f64();
    assert_eq!(
        rep_local[0].reduce_rounds, rep_proc[0].reduce_rounds,
        "LocalComm and ProcComm must report identical round counts"
    );
    assert_eq!(rep_local[0].iters, rep_proc[0].iters);
    for (a, bb) in x_local.iter().zip(&x_proc) {
        assert_eq!(
            a.to_bits(),
            bb.to_bits(),
            "proc solve must be bitwise identical to local solve"
        );
    }
    let variant = match &method {
        DistMethod::CaCg { s } => Variant::Ca(*s),
        _ => Variant::Standard,
    };
    let mut local = row_of(&variant, ranks, g * g, &rep_local, local_secs);
    local.variant.push_str("-local");
    let mut proc = row_of(&variant, ranks, g * g, &rep_proc, proc_secs);
    proc.variant.push_str("-proc");
    (local, proc)
}

fn main() {
    // process-transport worker re-exec target (the proc backend solves
    // below re-exec this bench binary)
    maybe_run_worker();

    let g = 96;
    let n = g * g;
    let mut rows: Vec<Row> = Vec::new();

    println!("# dist_scaling: standard vs pipelined vs CA-CG, Poisson2D g={g} (n={n}), RCB partition");
    println!(
        "| {:>9} | {:>5} | {:>6} | {:>7} | {:>11} | {:>12} | {:>9} |",
        "variant", "ranks", "iters", "rounds", "rounds/iter", "KB/iter/rank", "time"
    );
    println!("|-----------|-------|--------|---------|-------------|--------------|-----------|");

    let variants = [
        Variant::Standard,
        Variant::Pipelined,
        Variant::Ca(2),
        Variant::Ca(4),
        Variant::Ca(8),
    ];
    for &ranks in &[1usize, 2, 4] {
        for &variant in &variants {
            let (reports, secs) = measure(g, ranks, variant);
            let row = row_of(&variant, ranks, n, &reports, secs);
            print_row(&row);
            rows.push(row);
        }
    }

    // acceptance: the communication structure of Algorithm 1 / Appendix C
    let rounds_of = |name: &str, ranks: usize| -> (u64, f64) {
        let r = rows
            .iter()
            .find(|r| r.variant == name && r.ranks == ranks)
            .expect("row");
        (r.reduce_rounds, r.rounds_per_iter)
    };
    for row in &rows {
        assert!(
            row.converged,
            "{} at {} ranks did not converge",
            row.variant, row.ranks
        );
        if row.ranks >= 2 {
            match row.variant.as_str() {
                "standard" => assert!(
                    row.rounds_per_iter > 1.9 && row.rounds_per_iter < 2.2,
                    "standard CG must cost ~2 rounds/iter, got {:.2}",
                    row.rounds_per_iter
                ),
                "pipelined" => assert!(
                    row.rounds_per_iter < 1.2,
                    "pipelined CG must cost ~1 round/iter, got {:.2}",
                    row.rounds_per_iter
                ),
                _ => {}
            }
        }
    }
    // headline CA-CG claim: s=4 cuts reduction rounds >= 2x vs standard
    // CG at the same tolerance on the 4-rank Poisson problem
    let (std_rounds, _) = rounds_of("standard", 4);
    let (ca4_rounds, ca4_rpi) = rounds_of("ca-s4", 4);
    assert!(
        2 * ca4_rounds <= std_rounds,
        "CA-CG(s=4) must cut reduction rounds >=2x vs standard CG: {ca4_rounds} vs {std_rounds}"
    );
    println!(
        "\nCA-CG(s=4) at 4 ranks: {ca4_rounds} rounds vs standard {std_rounds} \
         ({:.1}x cut, {ca4_rpi:.2} rounds/iter)",
        std_rounds as f64 / ca4_rounds.max(1) as f64
    );

    // backend equivalence: thread ranks vs worker processes
    println!("\n# process transport (ProcComm, shm rings) vs thread ranks, g={g}, 4 ranks");
    for method in [DistMethod::Cg, DistMethod::CaCg { s: 4 }] {
        let (local, proc) = backend_parity(g, 4, method);
        print_row(&local);
        print_row(&proc);
        println!(
            "  -> identical rounds ({}) and bitwise-identical solution",
            proc.reduce_rounds
        );
        rows.push(local);
        rows.push(proc);
    }

    // weak scaling: ~fixed rows per rank (48^2), growing global problem
    println!("\n# weak scaling: ~{} rows per rank", 48 * 48);
    for &(ranks, wg) in &[(1usize, 48usize), (2, 68), (4, 96)] {
        for &variant in &[Variant::Standard, Variant::Ca(4)] {
            let (reports, secs) = measure(wg, ranks, variant);
            let mut row = row_of(&variant, ranks, wg * wg, &reports, secs);
            row.variant.push_str("-weak");
            print_row(&row);
            rows.push(row);
        }
    }

    // machine-readable trajectory for CI
    let mut json = String::from("{\n  \"bench\": \"dist_scaling\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"variant\": \"{}\", \"ranks\": {}, \"n\": {}, \"iterations\": {}, \"reduction_rounds\": {}, \"rounds_per_iter\": {:.4}, \"bytes_per_iter_per_rank\": {:.1}, \"wall_ms\": {:.2}}}{}\n",
            r.variant,
            r.ranks,
            r.n,
            r.iters,
            r.reduce_rounds,
            r.rounds_per_iter,
            r.bytes_per_iter_per_rank,
            r.wall_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_dist.json", &json).expect("write BENCH_dist.json");
    println!("\nwrote BENCH_dist.json ({} rows)", rows.len());
}
