//! Distributed scaling microbenchmark: standard (two-reduction) vs
//! pipelined (single-reduction) CG at 1/2/4 ranks, reporting the
//! communication structure the paper's Algorithm 1 and Appendix C pin:
//! iterations, reduction ROUNDS (latency units — the quantity pipelining
//! halves), and bytes sent per iteration (halo volume — identical for
//! both variants, since only the reductions are reorganized).
//!
//! Emits `BENCH_dist.json` next to the working directory so CI archives
//! a machine-readable perf trajectory.
//!
//! Run: cargo bench --bench dist_scaling

use std::sync::Arc;
use std::time::Instant;

use rsla::distributed::{dist_cg, dist_cg_pipelined, run_ranks, DistIterOpts, DistSolveReport};
use rsla::distributed::halo::distribute;
use rsla::distributed::partition::{partition, PartitionStrategy};
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::util::Prng;

struct Row {
    variant: &'static str,
    ranks: usize,
    n: usize,
    iters: usize,
    reduce_rounds: u64,
    rounds_per_iter: f64,
    bytes_per_iter_per_rank: f64,
    wall_ms: f64,
    converged: bool,
}

fn run_variant(g: usize, nparts: usize, pipelined: bool) -> (Vec<DistSolveReport>, f64) {
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let part = partition(&sys.matrix, Some(&sys.coords), nparts, PartitionStrategy::Rcb);
    let a_perm = sys.matrix.permute_sym(&part.perm);
    let shares = Arc::new(distribute(&a_perm, &part));
    let mut rng = Prng::new(g as u64);
    let b = Arc::new(rng.normal_vec(g * g));
    let part = Arc::new(part);
    let t0 = Instant::now();
    let reports = run_ranks(nparts, move |c| {
        let p = c.rank();
        let range = part.rank_range(p);
        let opts = DistIterOpts {
            tol: 1e-9,
            ..Default::default()
        };
        if pipelined {
            dist_cg_pipelined(&shares[p], &b[range], &c, &opts)
        } else {
            dist_cg(&shares[p], &b[range], &c, &opts)
        }
    });
    (reports, t0.elapsed().as_secs_f64())
}

fn main() {
    let g = 96;
    let n = g * g;
    let mut rows: Vec<Row> = Vec::new();

    println!("# dist_scaling: standard vs pipelined CG, Poisson2D g={g} (n={n}), RCB partition");
    println!(
        "| {:>9} | {:>5} | {:>6} | {:>7} | {:>11} | {:>12} | {:>9} |",
        "variant", "ranks", "iters", "rounds", "rounds/iter", "KB/iter/rank", "time"
    );
    println!("|-----------|-------|--------|---------|-------------|--------------|-----------|");

    for &ranks in &[1usize, 2, 4] {
        for &(variant, pipelined) in &[("standard", false), ("pipelined", true)] {
            let (reports, secs) = run_variant(g, ranks, pipelined);
            let iters = reports[0].iters.max(1);
            let rounds = reports[0].reduce_rounds;
            let max_sent = reports.iter().map(|r| r.bytes_sent).max().unwrap();
            let row = Row {
                variant,
                ranks,
                n,
                iters: reports[0].iters,
                reduce_rounds: rounds,
                rounds_per_iter: rounds as f64 / iters as f64,
                bytes_per_iter_per_rank: max_sent as f64 / iters as f64,
                wall_ms: secs * 1e3,
                converged: reports.iter().all(|r| r.converged),
            };
            println!(
                "| {:>9} | {:>5} | {:>6} | {:>7} | {:>11.2} | {:>12.2} | {:>6.1} ms |",
                row.variant,
                row.ranks,
                row.iters,
                row.reduce_rounds,
                row.rounds_per_iter,
                row.bytes_per_iter_per_rank / 1e3,
                row.wall_ms,
            );
            rows.push(row);
        }
    }

    // acceptance: the communication structure of Algorithm 1 / Appendix C
    for row in &rows {
        assert!(row.converged, "{} at {} ranks did not converge", row.variant, row.ranks);
        if row.ranks >= 2 {
            if row.variant == "standard" {
                assert!(
                    row.rounds_per_iter > 1.9 && row.rounds_per_iter < 2.2,
                    "standard CG must cost ~2 rounds/iter, got {:.2}",
                    row.rounds_per_iter
                );
            } else {
                assert!(
                    row.rounds_per_iter < 1.2,
                    "pipelined CG must cost ~1 round/iter, got {:.2}",
                    row.rounds_per_iter
                );
            }
        }
    }

    // machine-readable trajectory for CI
    let mut json = String::from("{\n  \"bench\": \"dist_scaling\",\n  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"variant\": \"{}\", \"ranks\": {}, \"n\": {}, \"iterations\": {}, \"reduction_rounds\": {}, \"rounds_per_iter\": {:.4}, \"bytes_per_iter_per_rank\": {:.1}, \"wall_ms\": {:.2}}}{}\n",
            r.variant,
            r.ranks,
            r.n,
            r.iters,
            r.reduce_rounds,
            r.rounds_per_iter,
            r.bytes_per_iter_per_rank,
            r.wall_ms,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_dist.json", &json).expect("write BENCH_dist.json");
    println!("\nwrote BENCH_dist.json ({} rows)", rows.len());
}
