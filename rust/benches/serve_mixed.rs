//! Mixed-family serving benchmark: the same open-loop workload (linear /
//! multi-RHS / nonlinear / eig / adjoint / distributed jobs on a small
//! set of recurring sparsity patterns) is driven through the solve
//! engine twice — pattern-affinity scheduling ON vs OFF (round-robin
//! worker assignment) — and the scheduling win is MEASURED, not
//! asserted from theory:
//!
//! * factor-cache hit rate must be strictly higher with affinity (a
//!   warm pattern is routed to the shard that holds its factor);
//! * cross-shard misses (factor exists, job landed elsewhere) must be
//!   strictly lower with affinity;
//! * client-observed p99 latency for linear jobs must not be worse with
//!   affinity — round-robin structurally pays one cold factorization
//!   per (pattern, shard) pair, affinity pays one per pattern.
//!
//! The bench also pins the `solve_into` satellite with a byte metric:
//! warm `CachedFactor::solve_into` applications (the `BlockDirect` and
//! AMG-coarse idiom) add NOTHING to the process-wide factor-solve
//! allocation tally — a measured zero, not a claim.
//!
//! A third series reruns the affinity config with the global rsla-trace
//! recorder ON and holds its client-observed linear p99 to within 5% of
//! the untraced run (plus a 0.5 ms noise floor): full-fidelity span
//! recording must stay in the measurement-noise band, or it is too
//! expensive to leave compiled into the serving path.
//!
//! Emits `BENCH_serve.json` for the CI perf trajectory.
//!
//! Run: cargo bench --bench serve_mixed

use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use rsla::backend::{Dispatcher, SolveOpts};
use rsla::distributed::DistIterOpts;
use rsla::eigen::LobpcgOpts;
use rsla::engine::{workload::MixedWorkload, Engine, EngineConfig, JobKind, JobSpec, SubmitOpts};
use rsla::factor_cache::FactorCache;
use rsla::iterative::{Amg, AmgOpts, Precond};
use rsla::metrics::mem::factor_solve_alloc_bytes;
use rsla::nonlinear::NewtonOpts;
use rsla::sparse::poisson::poisson2d;
use rsla::util::Prng;

const WORKERS: usize = 4;
const REQUESTS: usize = 420;
const WAVE: usize = 12;
const GRIDS: [usize; 3] = [40, 44, 48];

struct ConfigResult {
    label: &'static str,
    wall_s: f64,
    throughput: f64,
    /// Client-observed (submit -> reply) p99 seconds, per kind index.
    p99: [f64; 6],
    counts: [usize; 6],
    hit_rate: f64,
    cross_shard_misses: u64,
    shard_local_hits: u64,
    affinity_hits: u64,
    failures: usize,
}

fn p99_of(mut samples: Vec<f64>) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((0.99 * samples.len() as f64).ceil() as usize).clamp(1, samples.len());
    samples[idx - 1]
}

/// The shared mixed-family generator, with the family budgets bounded
/// so the measured phase is dominated by scheduling/placement effects
/// rather than open-ended iterative solves.
fn bench_workload(seed: u64) -> MixedWorkload {
    let mut w = MixedWorkload::new(&GRIDS, seed);
    w.newton = NewtonOpts {
        tol: 1e-8,
        max_iters: 12,
        ..Default::default()
    };
    w.eig = LobpcgOpts {
        tol: 1e-4,
        max_iters: 60,
        seed: 0,
    };
    w.dist = DistIterOpts {
        tol: 1e-8,
        ..Default::default()
    };
    w
}

fn run_config(affinity: bool, label: &'static str) -> ConfigResult {
    let engine = Engine::start(
        Arc::new(Dispatcher::new(None)),
        EngineConfig {
            workers: WORKERS,
            affinity,
            ..Default::default()
        },
    );
    let mut workload = bench_workload(1234);
    let mut rng = Prng::new(99);

    // Warm-up: one linear solve per pattern, so the measured phase
    // compares steady-state routing (affinity: every pattern warm on
    // its worker; round-robin: three (pattern, shard) pairs warm).
    for &g in &GRIDS {
        let sys = poisson2d(g, None);
        let n = sys.matrix.nrows;
        engine
            .submit(JobSpec::Linear {
                matrix: sys.matrix.clone(),
                b: rng.normal_vec(n),
                opts: SolveOpts::default(),
            })
            .expect("warmup admission")
            .wait()
            .outcome
            .expect("warmup solve");
    }

    // Measured phase: client-observed latency per job, paced in waves.
    let samples: Arc<Mutex<Vec<(usize, f64)>>> =
        Arc::new(Mutex::new(Vec::with_capacity(REQUESTS)));
    let mut failures = 0usize;
    let t0 = Instant::now();
    let mut submitted = 0usize;
    while submitted < REQUESTS {
        let wave = WAVE.min(REQUESTS - submitted);
        let (done_tx, done_rx) = channel::<bool>();
        for w in 0..wave {
            let i = submitted + w;
            let spec = workload.spec(i);
            let kind_idx = spec.kind().idx();
            let samples = samples.clone();
            let done = done_tx.clone();
            let start = Instant::now();
            engine
                .submit_with_reply(
                    spec,
                    SubmitOpts::default(),
                    Box::new(move |r| {
                        samples
                            .lock()
                            .unwrap()
                            .push((kind_idx, start.elapsed().as_secs_f64()));
                        let _ = done.send(r.outcome.is_ok());
                    }),
                )
                .expect("admission");
        }
        drop(done_tx);
        for ok in done_rx.iter().take(wave) {
            if !ok {
                failures += 1;
            }
        }
        submitted += wave;
    }
    let wall_s = t0.elapsed().as_secs_f64();

    let stats = engine.stats();
    let samples = samples.lock().unwrap();
    let mut p99 = [0.0f64; 6];
    let mut counts = [0usize; 6];
    for k in 0..6 {
        let of_kind: Vec<f64> = samples
            .iter()
            .filter(|(ki, _)| *ki == k)
            .map(|(_, s)| *s)
            .collect();
        counts[k] = of_kind.len();
        p99[k] = p99_of(of_kind);
    }
    let result = ConfigResult {
        label,
        wall_s,
        throughput: REQUESTS as f64 / wall_s,
        p99,
        counts,
        hit_rate: stats.cache_hit_rate(),
        cross_shard_misses: engine.metrics.get("factor_cache.cross_shard_miss"),
        shard_local_hits: engine.metrics.get("factor_cache.shard_local_hit"),
        affinity_hits: stats.affinity_hits,
        failures,
    };
    engine.shutdown();
    result
}

/// Satellite pin: warm `solve_into` applications allocate nothing —
/// the factor-solve byte tally (bumped by the allocating `solve` /
/// `solve_t` paths) must not move, neither for direct reuse of a cached
/// factor (the `BlockDirect` idiom) nor across AMG V-cycles (the
/// coarse-correction idiom).  Runs single-threaded BEFORE any engine
/// exists, so the process-global tally is quiet.
fn alloc_pin() -> (u64, u64) {
    let sys = poisson2d(32, None);
    let n = 1024;
    let cache = FactorCache::new(u64::MAX);
    let f = cache.factor(&sys.matrix, u64::MAX, None).expect("factor");
    let b = vec![1.0; n];
    let mut out = vec![0.0; n];
    let mut scratch = Vec::new();
    f.solve_into(&b, &mut out, &mut scratch).unwrap(); // prime buffers
    let before = factor_solve_alloc_bytes();
    for _ in 0..512 {
        f.solve_into(&b, &mut out, &mut scratch).unwrap();
    }
    let direct_delta = factor_solve_alloc_bytes() - before;
    assert_eq!(
        direct_delta, 0,
        "solve_into must not allocate on the warm path (allocated {direct_delta} bytes)"
    );
    // bitwise parity with the allocating path (this one solve MAY bump
    // the tally; measure it outside the pinned window)
    assert_eq!(f.solve(&b).unwrap(), out, "solve_into diverged from solve");

    let amg = Amg::new(&sys.matrix, &AmgOpts::default()).expect("amg hierarchy");
    let r = vec![1.0; n];
    let mut z = vec![0.0; n];
    amg.apply(&r, &mut z); // prime the coarse scratch buffer
    let before = factor_solve_alloc_bytes();
    for _ in 0..16 {
        amg.apply(&r, &mut z);
    }
    let amg_delta = factor_solve_alloc_bytes() - before;
    assert_eq!(
        amg_delta, 0,
        "AMG V-cycles must not touch the factor-solve tally (allocated {amg_delta} bytes)"
    );
    (direct_delta, amg_delta)
}

fn main() {
    println!("# serve_mixed: affinity vs round-robin scheduling");
    println!("# {WORKERS} workers, {REQUESTS} mixed jobs per config, grids {GRIDS:?}");

    let (direct_delta, amg_delta) = alloc_pin();
    println!("alloc pin (asserted 0): solve_into = {direct_delta} B, AMG V-cycle = {amg_delta} B");

    // untraced baselines FIRST: the traced series below must be
    // compared against numbers measured with the recorder fully off
    let rnd = run_config(false, "round_robin");
    let aff = run_config(true, "affinity");

    // traced series: identical affinity config, global recorder ON
    let tracer = rsla::trace::Tracer::global();
    tracer.enable();
    let traced = run_config(true, "affinity_traced");
    tracer.disable();
    let trace_records = {
        let snap = tracer.snapshot();
        snap.spans.len() + snap.convs.len()
    };

    for r in [&rnd, &aff, &traced] {
        println!(
            "{:>11}: {:.0} job/s, hit {:.1}%, xshard {}, local {}, lin p99 {:.2} ms, fail {}",
            r.label,
            r.throughput,
            100.0 * r.hit_rate,
            r.cross_shard_misses,
            r.shard_local_hits,
            r.p99[JobKind::Linear.idx()] * 1e3,
            r.failures,
        );
    }
    for r in [&rnd, &aff, &traced] {
        let kinds = ["linear", "multi_rhs", "nonlinear", "eig", "adjoint", "dist"];
        let per: Vec<String> = kinds
            .iter()
            .enumerate()
            .map(|(k, name)| format!("{name} {:.2}ms/{}", r.p99[k] * 1e3, r.counts[k]))
            .collect();
        println!("{} p99 by kind: {}", r.label, per.join(", "));
    }

    // acceptance: the scheduling win is measured
    assert_eq!(
        rnd.failures + aff.failures + traced.failures,
        0,
        "mixed workload had failures"
    );
    assert!(
        aff.hit_rate > rnd.hit_rate,
        "affinity hit rate {:.3} must beat round-robin {:.3}",
        aff.hit_rate,
        rnd.hit_rate
    );
    assert!(
        aff.cross_shard_misses < rnd.cross_shard_misses,
        "affinity cross-shard misses ({}) must be below round-robin ({})",
        aff.cross_shard_misses,
        rnd.cross_shard_misses
    );
    assert!(aff.affinity_hits > 0, "affinity routing never fired");
    // The counter assertions above are deterministic; this one compares
    // wall-clock distributions, so allow CI-runner noise headroom — the
    // structural gap (round-robin pays a cold factorization per
    // (pattern, shard) pair after warm-up, affinity pays none) is far
    // larger than 20%.
    let (ap99, rp99) = (
        aff.p99[JobKind::Linear.idx()],
        rnd.p99[JobKind::Linear.idx()],
    );
    assert!(
        ap99 <= rp99 * 1.2,
        "affinity linear p99 ({:.2} ms) must not exceed round-robin ({:.2} ms) + 20%",
        ap99 * 1e3,
        rp99 * 1e3
    );

    // tracing overhead contract: full span recording costs at most 5%
    // of linear p99 (a 0.5 ms absolute floor absorbs scheduler jitter
    // on runs where the baseline p99 is itself sub-millisecond)
    let tp99 = traced.p99[JobKind::Linear.idx()];
    let bound = (ap99 * 1.05).max(ap99 + 0.5e-3);
    assert!(
        tp99 <= bound,
        "traced linear p99 ({:.2} ms) exceeds the 5% overhead budget over untraced ({:.2} ms)",
        tp99 * 1e3,
        ap99 * 1e3
    );
    assert!(trace_records > 0, "traced series recorded no spans");
    println!(
        "tracing overhead: linear p99 {:.2} ms traced vs {:.2} ms untraced ({} records)",
        tp99 * 1e3,
        ap99 * 1e3,
        trace_records
    );

    // machine-readable trajectory for CI
    let kinds = ["linear", "multi_rhs", "nonlinear", "eig", "adjoint", "dist"];
    let mut json = String::from("{\n  \"bench\": \"serve_mixed\",\n");
    json.push_str(&format!(
        "  \"workers\": {WORKERS}, \"requests\": {REQUESTS}, \"grids\": [{}],\n",
        GRIDS.map(|g| g.to_string()).join(", ")
    ));
    json.push_str(&format!(
        "  \"alloc_bytes\": {{\"solve_into\": {direct_delta}, \"amg_vcycle\": {amg_delta}}},\n"
    ));
    json.push_str(&format!("  \"trace_records\": {trace_records},\n"));
    json.push_str("  \"configs\": [\n");
    for (i, r) in [&rnd, &aff, &traced].iter().enumerate() {
        let per_kind: Vec<String> = kinds
            .iter()
            .enumerate()
            .map(|(k, name)| {
                format!(
                    "{{\"kind\": \"{name}\", \"count\": {}, \"p99_ms\": {:.3}}}",
                    r.counts[k],
                    r.p99[k] * 1e3
                )
            })
            .collect();
        json.push_str(&format!(
            "    {{\"config\": \"{}\", \"wall_s\": {:.3}, \"throughput_jobs_per_s\": {:.1}, \"cache_hit_rate\": {:.4}, \"cross_shard_misses\": {}, \"shard_local_hits\": {}, \"affinity_hits\": {}, \"failures\": {}, \"p99_by_kind\": [{}]}}{}\n",
            r.label,
            r.wall_s,
            r.throughput,
            r.hit_rate,
            r.cross_shard_misses,
            r.shard_local_hits,
            r.affinity_hits,
            r.failures,
            per_kind.join(", "),
            if i == 2 { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_serve.json", &json).expect("write BENCH_serve.json");
    println!("\nwrote BENCH_serve.json (affinity vs round-robin, {REQUESTS} jobs each)");
}
