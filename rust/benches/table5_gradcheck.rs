//! Paper Table 5: gradient verification for the nonlinear and
//! eigenvalue adjoints vs central finite differences (Eq. 7,
//! eps = 1e-5), with forward/backward cost in units of solves.
//!
//! Run: cargo bench --bench table5_gradcheck

use std::rc::Rc;

use rsla::adjoint::{eigsh, solve_nonlinear};
use rsla::autograd::Tape;
use rsla::eigen::LobpcgOpts;
use rsla::nonlinear::{examples::QuadPoisson, newton, NewtonOpts, Residual};
use rsla::sparse::graphs::random_graph_laplacian;
use rsla::sparse::poisson::poisson2d;
use rsla::sparse::Pattern;
use rsla::util::{dot, Prng};

fn main() {
    let mut rng = Prng::new(0);
    println!("# Table 5: adjoint gradients vs central finite differences (eps = 1e-5)");
    println!();
    println!(
        "| {:<24} | {:>10} | {:>12} | {:>14} |",
        "Operation", "Rel. err.", "Fwd", "Bwd"
    );
    println!("|--------------------------|------------|--------------|----------------|");

    // ---------- eigenvalue (k = 6, LOBPCG + Hellmann-Feynman) ----------
    {
        let a = random_graph_laplacian(&mut rng, 150, 4, 0.5);
        let pattern = Pattern::of(&a);
        let k = 6;
        let opts = LobpcgOpts {
            tol: 1e-11,
            max_iters: 1500,
            seed: 3,
        };
        let tape = Tape::new();
        let vals = tape.leaf_vec(a.vals.clone());
        let (lams, res) = eigsh(&tape, &pattern, vals, k, &opts).unwrap();
        assert!(res.residuals.iter().all(|r| *r < 1e-7));
        let w: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
        let wv = tape.constant_vec(w.clone());
        let loss = tape.dot(lams, wv);
        let grads = tape.backward(loss);
        let dvals = grads.vec(vals).clone();

        // symmetric random direction FD
        let mut dir = vec![0.0; pattern.nnz()];
        let mut rng2 = Prng::new(9);
        for r in 0..pattern.nrows {
            for e in pattern.indptr[r]..pattern.indptr[r + 1] {
                let c = pattern.indices[e];
                if c >= r {
                    let v = rng2.normal();
                    dir[e] = v;
                    if let Some(es) = pattern.find(c, r) {
                        dir[es] = v;
                    }
                }
            }
        }
        let loss_of = |v: &[f64]| {
            let m = pattern.with_vals(v.to_vec());
            let pc = rsla::iterative::Jacobi::new(&m).unwrap();
            let r = rsla::eigen::lobpcg(&m, &pc, k, &opts);
            r.values.iter().zip(&w).map(|(l, wi)| l * wi).sum::<f64>()
        };
        let eps = 1e-5;
        let mut vp = a.vals.clone();
        let mut vm = a.vals.clone();
        for i in 0..dir.len() {
            vp[i] += eps * dir[i];
            vm[i] -= eps * dir[i];
        }
        let fd = (loss_of(&vp) - loss_of(&vm)) / (2.0 * eps);
        let analytic = dot(&dvals, &dir);
        let rel = (analytic - fd).abs() / fd.abs().max(1e-12);
        println!(
            "| {:<24} | {:>10.1e} | {:>12} | {:>14} |",
            format!("Eigenvalue (k={k})"),
            rel,
            "1 LOBPCG",
            "outer prod."
        );
        assert!(rel < 1e-4, "eigen rel err {rel}");
    }

    // ---------- nonlinear (5 Newton iterations) ----------
    {
        let g = 12;
        let n = g * g;
        let f0: Vec<f64> = (0..n).map(|_| 0.5 + rng.uniform()).collect();
        let w = rng.normal_vec(n);
        let factory: rsla::adjoint::nonlinear::ResidualFactory = Rc::new(move |theta: &[f64]| {
            Box::new(QuadPoisson {
                a: poisson2d(12, None).matrix,
                f: theta.to_vec(),
            }) as Box<dyn Residual>
        });
        let nopts = NewtonOpts {
            tol: 1e-14,
            max_iters: 5,
            fixed_iters: true, // paper: exactly 5 Newton solves forward
            ..Default::default()
        };
        let tape = Tape::new();
        let theta = tape.leaf_vec(f0.clone());
        let (u, res) = solve_nonlinear(&tape, factory.clone(), theta, &vec![0.0; n], &nopts).unwrap();
        assert_eq!(res.linear_solves, 5);
        let wv = tape.constant_vec(w.clone());
        let loss = tape.dot(u, wv);
        let grads = tape.backward(loss);
        let dtheta = grads.vec(theta).clone();

        let loss_of = |f: &[f64]| {
            let r = (factory)(f);
            let out = newton(r.as_ref(), &vec![0.0; n], &nopts);
            dot(&out.u, &w)
        };
        let check = rsla::gradcheck::check_direction(loss_of, &f0, &dtheta, 1e-5, 3, 11);
        println!(
            "| {:<24} | {:>10.1e} | {:>12} | {:>14} |",
            "Nonlinear (5 Newton)", check.rel_error, "5 solves", "1 solve"
        );
        assert!(check.rel_error < 1e-5, "nonlinear rel err {}", check.rel_error);
    }

    // ---------- linear (bonus row; §4.2 verifies it analytically) ----------
    {
        let g = 12;
        let n = g * g;
        let sys = poisson2d(g, None);
        let pattern = Pattern::of(&sys.matrix);
        let b = rng.normal_vec(n);
        let w = rng.normal_vec(n);
        let solver = rsla::adjoint::native_solver();
        let tape = Tape::new();
        let vals = tape.leaf_vec(sys.matrix.vals.clone());
        let bv = tape.leaf_vec(b.clone());
        let x = rsla::adjoint::solve_linear(&tape, &pattern, vals, bv, &solver).unwrap();
        let wv = tape.constant_vec(w.clone());
        let loss = tape.dot(x, wv);
        let grads = tape.backward(loss);
        let db = grads.vec(bv).clone();
        let loss_of = |bb: &[f64]| {
            let x = rsla::direct::direct_solve(&sys.matrix, bb).unwrap();
            dot(&x, &w)
        };
        let check = rsla::gradcheck::check_direction(loss_of, &b, &db, 1e-5, 3, 13);
        println!(
            "| {:<24} | {:>10.1e} | {:>12} | {:>14} |",
            "Linear (direct)", check.rel_error, "1 solve", "1 adj solve"
        );
        assert!(check.rel_error < 1e-6);
    }
    println!("\nall gradient checks within the paper's < 1e-5 band");
}
