//! Paper Table 4: distributed CG under a FIXED 1000-iteration budget
//! (Jacobi preconditioning only), reporting time, per-rank memory, and
//! the (deliberately unconverged) residual.
//!
//! The paper's point is memory capacity + per-iteration throughput of
//! the distributed forward/backward path, not convergence: with only
//! Jacobi, 1000 iterations leaves a ~1e-2 residual at 1e8 DOF.  Scaled
//! to this testbed (threads over channels instead of H200s over NCCL),
//! the same protocol: relative residual stays far from tol while DOF/s
//! scales near-linearly and per-rank bytes follow O(n/P + sqrt(n/P)).
//!
//! Run: cargo bench --bench table4_distributed

use rsla::distributed::{DSparseTensor, DistIterOpts, DistPrecondKind, PartitionStrategy};
use rsla::sparse::poisson::poisson2d;
use rsla::util::{self, Prng};

fn main() {
    println!("# Table 4 (scaled): distributed CG, fixed 1000-iteration budget, Jacobi only");
    println!("# ranks = threads + byte-accounted channels (NCCL stand-in); RCB partition");
    println!();
    println!(
        "| {:>9} | {:>5} | {:>9} | {:>11} | {:>10} | {:>10} | {:>11} |",
        "DOF", "ranks", "time", "Mem/rank", "Resid(rel)", "MDOF/s", "sent/rank"
    );
    println!("|-----------|-------|-----------|-------------|------------|------------|-------------|");

    // paper rows: 100M/4, 200M/3, 300M/3, 400M/3 -> scaled ~100x down
    let rows: &[(usize, usize)] = &[(256, 4), (512, 3), (640, 3), (768, 3)];
    let mut points: Vec<(f64, f64)> = Vec::new();
    for &(g, ranks) in rows {
        let n = g * g;
        let sys = poisson2d(g, None);
        let dt = DSparseTensor::from_global(
            &sys.matrix,
            Some(&sys.coords),
            ranks,
            PartitionStrategy::Rcb,
        )
        .expect("partition");
        let mut rng = Prng::new(g as u64);
        let b = rng.normal_vec(n);
        let bnorm = util::norm2(&b);

        let opts = DistIterOpts {
            tol: 0.0, // force the full budget, like the paper
            max_iters: 1000,
                ..Default::default()
            };
        let t0 = std::time::Instant::now();
        let (x, reports) = dt.solve(&b, &opts).unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let _ = &x;
        let rel_res = reports[0].residual / bnorm;
        let mem = reports.iter().map(|r| r.peak_bytes).max().unwrap();
        let sent = reports.iter().map(|r| r.bytes_sent).max().unwrap();
        let mdofs = (n as f64 * 1000.0) / secs / 1e6; // DOF-iterations/s /1e3... report DOF/s over the budget
        points.push((n as f64, secs));
        println!(
            "| {:>9} | {:>5} | {:>8.2} s | {:>8.2} MB | {:>10.1e} | {:>10.1} | {:>8.2} MB |",
            n,
            ranks,
            secs,
            mem as f64 / 1e6,
            rel_res,
            mdofs,
            sent as f64 / 1e6,
        );
    }

    // near-linear time fit (paper: T ~ n^1.05 from 1M to 100M)
    let logs: Vec<(f64, f64)> = points.iter().map(|(n, t)| (n.ln(), t.ln())).collect();
    let m = logs.len() as f64;
    let sx: f64 = logs.iter().map(|p| p.0).sum();
    let sy: f64 = logs.iter().map(|p| p.1).sum();
    let sxx: f64 = logs.iter().map(|p| p.0 * p.0).sum();
    let sxy: f64 = logs.iter().map(|p| p.0 * p.1).sum();
    let alpha = (m * sxy - sx * sy) / (m * sxx - sx * sx);
    println!();
    println!("fixed-budget time fit: T ~ n^{alpha:.2}  (paper: ~1.05; note rank count changes across rows)");
    println!("MDOF/s = DOF x 1000 iterations / wall seconds / 1e6");

    // ----- §5 future work, implemented: block-AMG preconditioning -----
    // Same fixed 1000-iteration budget; the paper's limitation ("the
    // residual stays in the 1e-2 range... needs a stronger
    // preconditioner e.g. algebraic multigrid") resolved by one-level
    // additive Schwarz with per-rank AMG V-cycles.
    println!("\n# extension: same budget with block-AMG (additive Schwarz) preconditioning");
    println!(
        "| {:>9} | {:>5} | {:>12} | {:>12} | {:>9} | {:>9} |",
        "DOF", "ranks", "jacobi resid", "amg resid", "jac iters", "amg iters"
    );
    for &(g, ranks) in rows {
        let n = g * g;
        let sys = poisson2d(g, None);
        let dt = DSparseTensor::from_global(
            &sys.matrix,
            Some(&sys.coords),
            ranks,
            PartitionStrategy::Rcb,
        )
        .unwrap();
        let mut rng = Prng::new(g as u64);
        let b = rng.normal_vec(n);
        let bnorm = util::norm2(&b);
        let run = |kind: DistPrecondKind| {
            let (_, reports) = dt
                .solve(
                    &b,
                    &DistIterOpts {
                        tol: 1e-10 * bnorm,
                        max_iters: 1000,
                        precond: kind,
                    },
                )
                .unwrap();
            (reports[0].residual / bnorm, reports[0].iters)
        };
        let (rj, ij) = run(DistPrecondKind::Jacobi);
        let (ra, ia) = run(DistPrecondKind::BlockAmg);
        println!(
            "| {:>9} | {:>5} | {:>12.1e} | {:>12.1e} | {:>9} | {:>9} |",
            n, ranks, rj, ra, ij, ia
        );
    }

    // halo surface-law check: per-rank halo vs sqrt(n/P)
    println!("\nhalo sizes (max over ranks) vs sqrt(n/P):");
    for &(g, ranks) in rows {
        let sys = poisson2d(g, None);
        let dt = DSparseTensor::from_global(
            &sys.matrix,
            Some(&sys.coords),
            ranks,
            PartitionStrategy::Rcb,
        )
        .unwrap();
        // bytes_per_rank is matrix-share only; reconstruct halo from a
        // 1-iteration probe
        let mut rng = Prng::new(1);
        let b = rng.normal_vec(g * g);
        let (_, reports) = dt
            .solve(
                &b,
                &DistIterOpts {
                    tol: 0.0,
                    max_iters: 1,
                ..Default::default()
            },
            )
            .unwrap();
        let per_iter_sent = reports.iter().map(|r| r.bytes_sent).max().unwrap() as f64;
        let sqrt_np = ((g * g) as f64 / ranks as f64).sqrt();
        println!(
            "  n={:>7} P={}  sent/iter/rank {:>8.0} B   8*sqrt(n/P) = {:>6.0} B",
            g * g,
            ranks,
            per_iter_sent,
            8.0 * sqrt_np
        );
    }
}
