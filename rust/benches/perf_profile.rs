//! Whole-stack profile (DESIGN.md §Perf step 1): per-layer hot-path
//! timings that direct the optimization pass.
//!
//!  L1/L2 (artifacts): per-call time of the stencil SpMV kernel and
//!      per-iteration time of the fused CG loop, across grid sizes —
//!      catches fusion cliffs in the lowered HLO.
//!  L3 (native): CSR SpMV GB/s, dot/axpy GB/s, halo pack/unpack, ELL
//!      conversion, tape overhead per adjoint solve.
//!
//! Run: cargo bench --bench perf_profile

use rsla::metrics::stopwatch::timed_median;
use rsla::runtime::{Arg, RuntimeHandle};
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::util::{self, Prng};

fn main() {
    l3_native_microbench();
    direct_path_breakdown();
    l1l2_artifact_profile();
}

/// Direct-solver phase breakdown: symbolic analysis vs numeric
/// (re)factorization vs triangular solve, for both the scalar envelope
/// kernel and the blocked supernodal kernel.  The numeric column is the
/// warm-path cost the factor cache pays per refactorization; trisolve
/// is the per-solve cost after that.
fn direct_path_breakdown() {
    use rsla::direct::{CholSymbolic, EnvelopeCholesky, SnCholSymbolic, SnCholesky, SupernodalOpts};
    use std::sync::Arc;

    println!("# direct path breakdown (symbolic / numeric / trisolve)");
    for &g in &[24usize, 48, 96] {
        let sys = poisson2d(g, None);
        let a = &sys.matrix;
        let n = a.nrows;
        let mut rng = Prng::new(4);
        let b = rng.normal_vec(n);

        // scalar envelope kernel
        let (esym, t_esym) = timed_median(5, || CholSymbolic::analyze(a, true).unwrap());
        let (env, t_enum) =
            timed_median(5, || EnvelopeCholesky::factor_numeric(&esym, &a.vals).unwrap());
        let mut out = vec![0.0; n];
        let mut scratch = vec![0.0; n];
        let (_, t_esol) = timed_median(7, || env.solve_into(&b, &mut out, &mut scratch));

        // blocked supernodal kernel
        let (snsym, t_ssym) = timed_median(5, || {
            SnCholSymbolic::analyze(a, true, &SupernodalOpts::default()).unwrap()
        });
        let snsym = Arc::new(snsym);
        let (snf, t_snum) =
            timed_median(5, || SnCholesky::factor_numeric(&snsym, &a.vals).unwrap());
        let (_, t_ssol) = timed_median(7, || snf.solve_into(&b, &mut out, &mut scratch));

        println!(
            "  g={g:>3} n={n:>6}: envelope  sym {:>8.1} us  num {:>9.1} us  tri {:>7.1} us",
            t_esym * 1e6,
            t_enum * 1e6,
            t_esol * 1e6
        );
        println!(
            "               supernodal sym {:>8.1} us  num {:>9.1} us  tri {:>7.1} us  ({} panels, max w {}, num speedup {:.2}x)",
            t_ssym * 1e6,
            t_snum * 1e6,
            t_ssol * 1e6,
            snsym.nsuper(),
            snsym.max_panel_width(),
            t_enum / t_snum
        );
    }
    println!();
}

fn l3_native_microbench() {
    println!("# L3 native micro-profile");
    // CSR SpMV bandwidth
    println!("## CSR SpMV");
    for &g in &[64usize, 128, 256, 512] {
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let n = g * g;
        let mut rng = Prng::new(0);
        let x = rng.normal_vec(n);
        let mut y = vec![0.0; n];
        let reps = (50_000_000 / n).max(3);
        let (_, secs) = timed_median(5, || {
            for _ in 0..reps {
                sys.matrix.spmv(&x, &mut y);
            }
        });
        let per = secs / reps as f64;
        // bytes touched per spmv: vals + indices + x-gather + y-write
        let bytes = (sys.matrix.nnz() * (8 + 8) + n * 16) as f64;
        println!(
            "  g={g:>4} n={n:>7}: {:>8.2} us/spmv  {:>6.2} GB/s  {:>7.0} Mnnz/s",
            per * 1e6,
            bytes / per / 1e9,
            sys.matrix.nnz() as f64 / per / 1e6
        );
    }
    // dot + axpy
    println!("## dot / axpy");
    for &n in &[65_536usize, 1_048_576] {
        let mut rng = Prng::new(1);
        let x = rng.normal_vec(n);
        let mut y = rng.normal_vec(n);
        let reps = (200_000_000 / n).max(3);
        let (_, sd) = timed_median(5, || {
            let mut acc = 0.0;
            for _ in 0..reps {
                acc += util::dot(&x, &y);
            }
            acc
        });
        let (_, sa) = timed_median(5, || {
            for _ in 0..reps {
                util::axpy_inplace(1.0000001, &x, &mut y);
            }
        });
        println!(
            "  n={n:>8}: dot {:>7.2} GB/s   axpy {:>7.2} GB/s",
            (n * 16) as f64 / (sd / reps as f64) / 1e9,
            (n * 24) as f64 / (sa / reps as f64) / 1e9
        );
    }
    // halo pack/unpack (the distributed hot loop outside SpMV)
    println!("## halo exchange round (P=4, RCB)");
    for &g in &[128usize, 256] {
        use rsla::distributed::{DistIterOpts, DSparseTensor, PartitionStrategy};
        let sys = poisson2d(g, None);
        let dt = DSparseTensor::from_global(
            &sys.matrix,
            Some(&sys.coords),
            4,
            PartitionStrategy::Rcb,
        )
        .unwrap();
        let mut rng = Prng::new(2);
        let b = rng.normal_vec(g * g);
        let iters = 200;
        let t0 = std::time::Instant::now();
        let _ = dt.solve(
            &b,
            &DistIterOpts {
                tol: 0.0,
                max_iters: iters,
                ..Default::default()
            },
        );
        let per_it = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "  g={g:>4} n={:>7}: {:>8.1} us/iteration (spmv+halo+2 reduce, 4 threads)",
            g * g,
            per_it * 1e6
        );
    }
    // ELL conversion cost (xla-cg preprocessing)
    println!("## ELL conversion (xla-cg preprocessing)");
    for &g in &[64usize, 128] {
        let sys = poisson2d(g, None);
        let (_, secs) = timed_median(5, || rsla::sparse::graphs::to_ell(&sys.matrix, 8));
        println!("  n={:>7}: {:>8.2} us", g * g, secs * 1e6);
    }
    println!();
}

fn l1l2_artifact_profile() {
    println!("# L1/L2 artifact profile (PJRT CPU)");
    let rt = match RuntimeHandle::spawn_default() {
        Ok(r) => r,
        Err(e) => {
            println!("skipped (no artifacts: {e})");
            return;
        }
    };
    println!("## stencil_spmv per call");
    for &g in &[32usize, 64, 128, 256, 512] {
        let name = format!("stencil_spmv_g{g}");
        if !rt.has(&name) {
            continue;
        }
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let mut rng = Prng::new(0);
        let x = rng.normal_vec(g * g);
        let args = [
            Arg::tensor(sys.coeffs.to_planes(), vec![5, g, g]),
            Arg::tensor(x, vec![g, g]),
        ];
        let _ = rt.run(&name, &args); // warm compile
        let (_, secs) = timed_median(7, || rt.run(&name, &args).unwrap());
        println!(
            "  g={g:>4} n={:>7}: {:>9.1} us/call  {:>7.1} MDOF/s",
            g * g,
            secs * 1e6,
            (g * g) as f64 / secs / 1e6
        );
    }
    println!("## fused cg_poisson per iteration (forced k=100, tol=0)");
    for &g in &[32usize, 64, 128, 256, 512] {
        let name = format!("cg_poisson_g{g}");
        if !rt.has(&name) {
            continue;
        }
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let mut rng = Prng::new(0);
        let b = rng.normal_vec(g * g);
        let args = [
            Arg::tensor(sys.coeffs.to_planes(), vec![5, g, g]),
            Arg::tensor(b, vec![g, g]),
            Arg::ScalarI32(100),
            Arg::ScalarF64(0.0),
        ];
        let _ = rt.run(&name, &args); // warm compile
        let (_, secs) = timed_median(5, || rt.run(&name, &args).unwrap());
        let per_it = secs / 100.0;
        println!(
            "  g={g:>4} n={:>7}: {:>9.1} us/iter  {:>7.1} MDOF/s  (vs native spmv above)",
            g * g,
            per_it * 1e6,
            (g * g) as f64 / per_it / 1e6
        );
    }
    println!("## cg_ell per iteration (forced k=100)");
    for &(n, s) in &[(4096usize, 8usize), (16384, 8), (65536, 8)] {
        let name = format!("cg_ell_n{n}_s{s}");
        if !rt.has(&name) {
            continue;
        }
        let mut rng = Prng::new(3);
        let a = rsla::sparse::graphs::bounded_degree_laplacian(&mut rng, n, 7, 0.5);
        let (cols, vals) = rsla::sparse::graphs::to_ell(&a, s).unwrap();
        let args = [
            Arg::I32(std::sync::Arc::new(cols), vec![n, s]),
            Arg::tensor(vals, vec![n, s]),
            Arg::vec(a.diag()),
            Arg::vec(rng.normal_vec(n)),
            Arg::ScalarI32(100),
            Arg::ScalarF64(0.0),
        ];
        let _ = rt.run(&name, &args);
        let (_, secs) = timed_median(5, || rt.run(&name, &args).unwrap());
        println!(
            "  n={n:>7} s={s}: {:>9.1} us/iter  {:>7.1} MDOF/s",
            secs / 100.0 * 1e6,
            n as f64 / (secs / 100.0) / 1e6
        );
    }
}
