//! Paper Fig. 2 + Table 7: adjoint vs naive backprop through k CG
//! iterations.
//!
//! Protocol (paper §4.2): same unpreconditioned CG forward, two
//! gradient paths —
//!   * naive: every iteration on the autograd tape, SpMV recorded as
//!     the scatter decomposition (two nnz-sized intermediates/iter);
//!   * adjoint: ONE tape node; backward = one CG solve run to the same
//!     k plus the O(nnz) outer product.
//! Sweep k; report tape memory (flat vs linear), backward time (flat-ish
//! vs linear), and the ratio.  A simulated device budget reproduces the
//! paper's OOM rows at large k.  Grid scaled from the paper's
//! n = 640,000 to n = 10,000 (CPU container).
//!
//! Also runs the paper's small-problem convergence-agreement check
//! (both paths run to convergence -> loss to machine precision, db
//! tight, dA looser).
//!
//! Run: cargo bench --bench fig2_table7_adjoint_vs_naive

use rsla::adjoint::{solve_linear, SolveFn, Transpose};
use rsla::autograd::naive_cg::{naive_cg, naive_cg_tol, TapeSpmv};
use rsla::autograd::Tape;
use rsla::iterative::{cg, Identity, IterOpts};
use rsla::sparse::poisson::poisson2d;
use rsla::sparse::Pattern;
use rsla::util::{self, Prng};
use std::sync::Arc;

/// Adjoint-path solver: unpreconditioned CG run to the same budget as
/// the forward (the paper's protocol), with an optional atol stop for
/// the convergence-agreement check.
fn k_iteration_solver(k: usize, tol: f64) -> SolveFn {
    Arc::new(move |pattern: &Pattern, vals: &[f64], rhs: &[f64], _t: Transpose| {
        let a = pattern.with_vals(vals.to_vec());
        let r = cg(
            &a,
            rhs,
            &Identity,
            &IterOpts {
                tol,
                max_iters: k,
                record_history: false,
            },
            None,
        );
        Ok(r.x)
    })
}

fn main() {
    let g = 100; // n = 10,000 (paper: 640,000 on a 96 GB GPU)
    let n = g * g;
    let sys = poisson2d(g, None);
    let pattern = Pattern::of(&sys.matrix);
    let spmv = TapeSpmv::new(&pattern);
    let mut rng = Prng::new(0);
    let bv = rng.normal_vec(n);
    // simulated device budget for the naive tape (paper: 96 GB; scaled
    // by the same ~64x memory ratio: 1.5 GB)
    let budget: usize = 1_500_000_000;

    println!("# Fig 2 / Table 7 (scaled): adjoint vs naive CG backprop, n = {n} (2D Poisson)");
    println!("# naive tape budget {} GB simulates the paper's 96 GB device", budget as f64 / 1e9);
    println!();
    println!(
        "| {:>5} | {:>10} | {:>10} | {:>9} | {:>9} | {:>6} | {:>11} |",
        "k", "adj mem", "naive mem", "adj bwd", "naive bwd", "ratio", "mem ratio"
    );
    println!("|-------|------------|------------|-----------|-----------|--------|-------------|");

    for &k in &[10usize, 50, 100, 200, 500, 1000, 2000, 5000] {
        // ---- adjoint path ----
        let solver = k_iteration_solver(k, 0.0);
        let t_adj = Tape::new();
        let vals_a = t_adj.leaf_vec(sys.matrix.vals.clone());
        let b_a = t_adj.leaf_vec(bv.clone());
        let x_a = solve_linear(&t_adj, &pattern, vals_a, b_a, &solver).unwrap();
        let loss_a = t_adj.dot(x_a, x_a);
        let adj_mem = t_adj.forward_bytes();
        let t0 = std::time::Instant::now();
        let g_adj = t_adj.backward(loss_a);
        let adj_bwd = t0.elapsed().as_secs_f64();
        let _ = g_adj.vec(b_a);

        // ---- naive path (estimate first; obey the budget) ----
        // per iteration: gather(nnz) + mul(nnz) + index_add(n) + 2 dot
        // + 2 mul_sv(n) + add/sub(n)... measured below when it fits.
        let per_iter_estimate = (2 * pattern.nnz() + 6 * n) * 8;
        let naive_fits = per_iter_estimate * k <= budget;
        let (naive_mem_s, naive_bwd_s, ratio_s, memratio_s) = if naive_fits {
            let t_nv = Tape::new();
            let vals_n = t_nv.leaf_vec(sys.matrix.vals.clone());
            let b_n = t_nv.leaf_vec(bv.clone());
            let x_n = naive_cg(&t_nv, &spmv, vals_n, b_n, k);
            let loss_n = t_nv.dot(x_n, x_n);
            let naive_mem = t_nv.forward_bytes();
            let t1 = std::time::Instant::now();
            let g_nv = t_nv.backward(loss_n);
            let naive_bwd = t1.elapsed().as_secs_f64();
            let _ = g_nv.vec(b_n);
            (
                format!("{:.2} GB", naive_mem as f64 / 1e9),
                format!("{:.0} ms", naive_bwd * 1e3),
                format!("{:.0}x", naive_bwd / adj_bwd.max(1e-9)),
                format!("{:.0}x", naive_mem as f64 / adj_mem as f64),
            )
        } else {
            ("OOM".into(), "—".into(), "—".into(), "—".into())
        };
        println!(
            "| {:>5} | {:>7.0} MB | {:>10} | {:>6.0} ms | {:>9} | {:>6} | {:>11} |",
            k,
            adj_mem as f64 / 1e6,
            naive_mem_s,
            adj_bwd * 1e3,
            naive_bwd_s,
            ratio_s,
            memratio_s,
        );
    }

    // ---- small-problem convergence agreement (paper: n_grid = 64) ----
    println!("\n# convergence-agreement check (paper: n_grid=64, both paths to convergence)");
    let g2 = 64;
    let n2 = g2 * g2;
    let sys2 = poisson2d(g2, None);
    let pattern2 = Pattern::of(&sys2.matrix);
    let spmv2 = TapeSpmv::new(&pattern2);
    let mut rng2 = Prng::new(1);
    let b2 = rng2.normal_vec(n2);
    let k_conv = 3000; // paper: atol = 1e-12, k = 3000
    let atol = 1e-12;

    let t_nv = Tape::new();
    let vn = t_nv.leaf_vec(sys2.matrix.vals.clone());
    let bn = t_nv.leaf_vec(b2.clone());
    let xn = naive_cg_tol(&t_nv, &spmv2, vn, bn, k_conv, atol);
    let ln = t_nv.dot(xn, xn);
    let gn = t_nv.backward(ln);

    let solver2 = k_iteration_solver(k_conv, atol);
    let t_ad = Tape::new();
    let va = t_ad.leaf_vec(sys2.matrix.vals.clone());
    let ba = t_ad.leaf_vec(b2.clone());
    let xa = solve_linear(&t_ad, &pattern2, va, ba, &solver2).unwrap();
    let la = t_ad.dot(xa, xa);
    let ga = t_ad.backward(la);

    let loss_rel = ((t_nv.scalar_of(ln) - t_ad.scalar_of(la)) / t_ad.scalar_of(la)).abs();
    let db_rel = util::rel_l2(gn.vec(bn), ga.vec(ba));
    let da_rel = util::rel_l2(gn.vec(vn), ga.vec(va));
    println!("loss rel err  {loss_rel:.2e}   (paper: 1.96e-16)");
    println!("dL/db rel err {db_rel:.2e}   (paper: 2.6e-14)");
    println!("dL/dA rel err {da_rel:.2e}   (paper: 6.8e-4; naive accumulates roundoff over k)");
    assert!(loss_rel < 1e-10 && db_rel < 1e-6 && da_rel < 1e-2);
}
