//! Repeated-adjoint-solve microbenchmark: the factor cache vs the
//! seed's refactor-every-call path.
//!
//! Scenario (the inverse-learning / training-loop shape, paper Fig. 3):
//! K forward+backward passes over ONE matrix.  The seed's
//! `Dispatcher::solver_fn` re-checked symmetry in O(nnz) and re-ran a
//! full factorization on EVERY call — forward and backward alike.  The
//! cached path performs one numeric factorization total and serves
//! every subsequent solve (including the `Transpose::Yes` adjoint
//! solves) from it.
//!
//! A second scenario changes the values every step (the Newton shape):
//! there the cache's numeric tier cannot hit, but the symbolic tier
//! (ordering, elimination structure, fill allocation) still carries
//! across steps.
//!
//! Run: cargo bench --bench factor_cache_repeat
//!
//! The harness asserts the >= 2x acceptance speedup on the fixed-values
//! scenario.

use std::sync::Arc;
use std::time::Instant;

use rsla::adjoint::Transpose;
use rsla::backend::{Dispatcher, SolveOpts};
use rsla::direct::{direct_solve, SparseLu};
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::sparse::Pattern;
use rsla::util::Prng;

fn main() {
    let g = 48;
    let n = g * g;
    let steps = 30;
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let pattern = Pattern::of(&sys.matrix);
    let mut rng = Prng::new(42);
    let rhs: Vec<Vec<f64>> = (0..steps).map(|_| rng.normal_vec(n)).collect();
    let gys: Vec<Vec<f64>> = (0..steps).map(|_| rng.normal_vec(n)).collect();

    // --- seed path: symmetry scan + full factorization per call ------
    let t0 = Instant::now();
    let mut acc_uncached = 0.0f64;
    for k in 0..steps {
        let a = pattern.with_vals(sys.matrix.vals.clone());
        let _sym = a.is_symmetric(1e-12);
        let x = direct_solve(&a, &rhs[k]).unwrap();
        let _sym = a.is_symmetric(1e-12);
        let lam = direct_solve(&a, &gys[k]).unwrap(); // adjoint of symmetric A
        acc_uncached += x[0] + lam[0];
    }
    let uncached = t0.elapsed().as_secs_f64();

    // --- cached path: Dispatcher::solver_fn over the factor cache ----
    let d = Arc::new(Dispatcher::new(None));
    let f = d.solver_fn(SolveOpts::default());
    // warm nothing: include the single cold factorization in the timing
    let t0 = Instant::now();
    let mut acc_cached = 0.0f64;
    for k in 0..steps {
        let x = f(&pattern, &sys.matrix.vals, &rhs[k], Transpose::No).unwrap();
        let lam = f(&pattern, &sys.matrix.vals, &gys[k], Transpose::Yes).unwrap();
        acc_cached += x[0] + lam[0];
    }
    let cached = t0.elapsed().as_secs_f64();

    assert!(
        (acc_uncached - acc_cached).abs() < 1e-6 * (1.0 + acc_uncached.abs()),
        "cached and uncached paths disagree"
    );
    let speedup = uncached / cached;
    println!("repeated-adjoint-solve microbenchmark (g={g}, n={n}, {steps} fwd+bwd steps)");
    println!(
        "  uncached (refactor every call): {:8.1} ms  ({:.2} ms/step)",
        uncached * 1e3,
        uncached * 1e3 / steps as f64
    );
    println!(
        "  cached   (factorize once):      {:8.1} ms  ({:.2} ms/step)",
        cached * 1e3,
        cached * 1e3 / steps as f64
    );
    println!("  speedup: {speedup:.1}x");
    println!(
        "  cache counters: {:?}",
        d.metrics
            .snapshot()
            .into_iter()
            .filter(|(k, _)| k.starts_with("factor_cache"))
            .collect::<Vec<_>>()
    );

    // --- Newton shape: values change every step (symbolic tier) ------
    let mut rng = Prng::new(7);
    let nonsym = rsla::sparse::graphs::random_nonsymmetric(&mut rng, 1500, 6);
    let npat = Pattern::of(&nonsym);
    let scales: Vec<f64> = (0..steps).map(|_| 1.0 + 0.1 * rng.uniform()).collect();
    let b = rng.normal_vec(1500);

    let t0 = Instant::now();
    for s in &scales {
        let vals: Vec<f64> = nonsym.vals.iter().map(|v| v * s).collect();
        let a = npat.with_vals(vals);
        let f = SparseLu::factor(&a).unwrap(); // seed: full symbolic+numeric
        let _ = f.solve_t(&b).unwrap();
    }
    let cold_lu = t0.elapsed().as_secs_f64();

    let d2 = Arc::new(Dispatcher::new(None));
    let fc = d2.solver_fn(SolveOpts::default());
    let t0 = Instant::now();
    for s in &scales {
        let vals: Vec<f64> = nonsym.vals.iter().map(|v| v * s).collect();
        let _ = fc(&npat, &vals, &b, Transpose::Yes).unwrap();
    }
    let warm_lu = t0.elapsed().as_secs_f64();
    println!("\nchanging-values (Newton-shaped) adjoint solves, LU n=1500:");
    println!("  cold symbolic+numeric per step: {:8.1} ms", cold_lu * 1e3);
    println!("  symbolic reuse (refactor only): {:8.1} ms", warm_lu * 1e3);
    println!("  speedup: {:.1}x", cold_lu / warm_lu);

    assert!(
        speedup >= 2.0,
        "acceptance: repeated-adjoint-solve speedup must be >= 2x, got {speedup:.2}x"
    );

    supernodal_vs_column_series(speedup);
}

/// Blocked (supernodal) vs scalar column numeric kernels on the
/// poisson2d family, plus the blocked LU replay on a nonsymmetric
/// matrix.  Emits `BENCH_factor.json` for the CI perf trajectory.
///
/// Acceptance: the blocked Cholesky numeric phase must be >= 1.5x
/// faster than the scalar envelope kernel on the largest poisson2d
/// grid in the series.
fn supernodal_vs_column_series(repeat_speedup: f64) {
    use rsla::direct::{CholSymbolic, EnvelopeCholesky, LuPanels, SnCholSymbolic, SnCholesky,
                       SupernodalOpts};
    use rsla::metrics::stopwatch::timed_median;

    struct Row {
        matrix: String,
        n: usize,
        kernel: &'static str,
        panels: usize,
        max_width: usize,
        numeric_us: f64,
        trisolve_us: f64,
    }
    let mut rows: Vec<Row> = Vec::new();

    println!("\nsupernodal vs column numeric kernels (poisson2d family):");
    for &g in &[24usize, 48, 96] {
        let sys = poisson2d(g, Some(&kappa_star(g)));
        let a = &sys.matrix;
        let n = a.nrows;
        let mut rng = Prng::new(g as u64);
        let b = rng.normal_vec(n);
        let mut out = vec![0.0; n];
        let mut scratch = vec![0.0; n];

        let esym = CholSymbolic::analyze(a, true).unwrap();
        let (env, t_col) =
            timed_median(5, || EnvelopeCholesky::factor_numeric(&esym, &a.vals).unwrap());
        let (_, t_col_tri) = timed_median(7, || env.solve_into(&b, &mut out, &mut scratch));
        rows.push(Row {
            matrix: format!("poisson2d({g})"),
            n,
            kernel: "column",
            panels: n,
            max_width: 1,
            numeric_us: t_col * 1e6,
            trisolve_us: t_col_tri * 1e6,
        });

        let snsym =
            Arc::new(SnCholSymbolic::analyze(a, true, &SupernodalOpts::default()).unwrap());
        let (snf, t_sn) =
            timed_median(5, || SnCholesky::factor_numeric(&snsym, &a.vals).unwrap());
        let (_, t_sn_tri) = timed_median(7, || snf.solve_into(&b, &mut out, &mut scratch));
        rows.push(Row {
            matrix: format!("poisson2d({g})"),
            n,
            kernel: "supernodal",
            panels: snsym.nsuper(),
            max_width: snsym.max_panel_width(),
            numeric_us: t_sn * 1e6,
            trisolve_us: t_sn_tri * 1e6,
        });

        println!(
            "  poisson2d({g:>2}) n={n:>5}: column {:>9.1} us  supernodal {:>9.1} us  ({:.2}x, {} panels, max w {})",
            t_col * 1e6,
            t_sn * 1e6,
            t_col / t_sn,
            snsym.nsuper(),
            snsym.max_panel_width()
        );

        if g == 96 {
            assert!(
                t_col / t_sn >= 1.5,
                "acceptance: supernodal numeric must be >= 1.5x the column kernel \
                 on poisson2d({g}), got {:.2}x",
                t_col / t_sn
            );
        }
    }

    // blocked LU replay vs the recorded column replay (warm path)
    let mut rng = Prng::new(11);
    let nonsym = rsla::sparse::graphs::random_nonsymmetric(&mut rng, 2000, 6);
    let (_, lsym) = SparseLu::factor_recording(&nonsym, usize::MAX).unwrap();
    let (_, t_lu_col) =
        timed_median(5, || SparseLu::refactor(&lsym, &nonsym, usize::MAX).unwrap());
    let plan = LuPanels::plan(&lsym, &SupernodalOpts::default());
    let lu_line = if plan.engaged() {
        let (_, t_lu_blk) = timed_median(5, || {
            SparseLu::refactor_blocked(&lsym, &plan, &nonsym, usize::MAX).unwrap()
        });
        rows.push(Row {
            matrix: "random_nonsymmetric(2000)".to_string(),
            n: 2000,
            kernel: "column",
            panels: 2000,
            max_width: 1,
            numeric_us: t_lu_col * 1e6,
            trisolve_us: 0.0,
        });
        rows.push(Row {
            matrix: "random_nonsymmetric(2000)".to_string(),
            n: 2000,
            kernel: "supernodal",
            panels: plan.npanels(),
            max_width: plan.max_panel_width(),
            numeric_us: t_lu_blk * 1e6,
            trisolve_us: 0.0,
        });
        format!(
            "  LU n=2000 refactor: column {:>9.1} us  blocked {:>9.1} us  ({:.2}x, {} panels)",
            t_lu_col * 1e6,
            t_lu_blk * 1e6,
            t_lu_col / t_lu_blk,
            plan.npanels()
        )
    } else {
        format!("  LU n=2000: panel plan disengaged (max width {})", plan.max_panel_width())
    };
    println!("{lu_line}");

    let mut json = String::from("{\n  \"bench\": \"factor_cache_repeat\",\n");
    json.push_str(&format!("  \"repeat_speedup\": {repeat_speedup:.3},\n"));
    json.push_str("  \"rows\": [\n");
    for (i, r) in rows.iter().enumerate() {
        json.push_str(&format!(
            "    {{\"matrix\": \"{}\", \"n\": {}, \"kernel\": \"{}\", \"panels\": {}, \"max_width\": {}, \"numeric_us\": {:.2}, \"trisolve_us\": {:.2}}}{}\n",
            r.matrix,
            r.n,
            r.kernel,
            r.panels,
            r.max_width,
            r.numeric_us,
            r.trisolve_us,
            if i + 1 == rows.len() { "" } else { "," }
        ));
    }
    json.push_str("  ]\n}\n");
    std::fs::write("BENCH_factor.json", &json).expect("write BENCH_factor.json");
    println!("wrote BENCH_factor.json ({} rows)", rows.len());
}
