//! Repeated-adjoint-solve microbenchmark: the factor cache vs the
//! seed's refactor-every-call path.
//!
//! Scenario (the inverse-learning / training-loop shape, paper Fig. 3):
//! K forward+backward passes over ONE matrix.  The seed's
//! `Dispatcher::solver_fn` re-checked symmetry in O(nnz) and re-ran a
//! full factorization on EVERY call — forward and backward alike.  The
//! cached path performs one numeric factorization total and serves
//! every subsequent solve (including the `Transpose::Yes` adjoint
//! solves) from it.
//!
//! A second scenario changes the values every step (the Newton shape):
//! there the cache's numeric tier cannot hit, but the symbolic tier
//! (ordering, elimination structure, fill allocation) still carries
//! across steps.
//!
//! Run: cargo bench --bench factor_cache_repeat
//!
//! The harness asserts the >= 2x acceptance speedup on the fixed-values
//! scenario.

use std::sync::Arc;
use std::time::Instant;

use rsla::adjoint::Transpose;
use rsla::backend::{Dispatcher, SolveOpts};
use rsla::direct::{direct_solve, SparseLu};
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::sparse::Pattern;
use rsla::util::Prng;

fn main() {
    let g = 48;
    let n = g * g;
    let steps = 30;
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let pattern = Pattern::of(&sys.matrix);
    let mut rng = Prng::new(42);
    let rhs: Vec<Vec<f64>> = (0..steps).map(|_| rng.normal_vec(n)).collect();
    let gys: Vec<Vec<f64>> = (0..steps).map(|_| rng.normal_vec(n)).collect();

    // --- seed path: symmetry scan + full factorization per call ------
    let t0 = Instant::now();
    let mut acc_uncached = 0.0f64;
    for k in 0..steps {
        let a = pattern.with_vals(sys.matrix.vals.clone());
        let _sym = a.is_symmetric(1e-12);
        let x = direct_solve(&a, &rhs[k]).unwrap();
        let _sym = a.is_symmetric(1e-12);
        let lam = direct_solve(&a, &gys[k]).unwrap(); // adjoint of symmetric A
        acc_uncached += x[0] + lam[0];
    }
    let uncached = t0.elapsed().as_secs_f64();

    // --- cached path: Dispatcher::solver_fn over the factor cache ----
    let d = Arc::new(Dispatcher::new(None));
    let f = d.solver_fn(SolveOpts::default());
    // warm nothing: include the single cold factorization in the timing
    let t0 = Instant::now();
    let mut acc_cached = 0.0f64;
    for k in 0..steps {
        let x = f(&pattern, &sys.matrix.vals, &rhs[k], Transpose::No).unwrap();
        let lam = f(&pattern, &sys.matrix.vals, &gys[k], Transpose::Yes).unwrap();
        acc_cached += x[0] + lam[0];
    }
    let cached = t0.elapsed().as_secs_f64();

    assert!(
        (acc_uncached - acc_cached).abs() < 1e-6 * (1.0 + acc_uncached.abs()),
        "cached and uncached paths disagree"
    );
    let speedup = uncached / cached;
    println!("repeated-adjoint-solve microbenchmark (g={g}, n={n}, {steps} fwd+bwd steps)");
    println!(
        "  uncached (refactor every call): {:8.1} ms  ({:.2} ms/step)",
        uncached * 1e3,
        uncached * 1e3 / steps as f64
    );
    println!(
        "  cached   (factorize once):      {:8.1} ms  ({:.2} ms/step)",
        cached * 1e3,
        cached * 1e3 / steps as f64
    );
    println!("  speedup: {speedup:.1}x");
    println!(
        "  cache counters: {:?}",
        d.metrics
            .snapshot()
            .into_iter()
            .filter(|(k, _)| k.starts_with("factor_cache"))
            .collect::<Vec<_>>()
    );

    // --- Newton shape: values change every step (symbolic tier) ------
    let mut rng = Prng::new(7);
    let nonsym = rsla::sparse::graphs::random_nonsymmetric(&mut rng, 1500, 6);
    let npat = Pattern::of(&nonsym);
    let scales: Vec<f64> = (0..steps).map(|_| 1.0 + 0.1 * rng.uniform()).collect();
    let b = rng.normal_vec(1500);

    let t0 = Instant::now();
    for s in &scales {
        let vals: Vec<f64> = nonsym.vals.iter().map(|v| v * s).collect();
        let a = npat.with_vals(vals);
        let f = SparseLu::factor(&a).unwrap(); // seed: full symbolic+numeric
        let _ = f.solve_t(&b).unwrap();
    }
    let cold_lu = t0.elapsed().as_secs_f64();

    let d2 = Arc::new(Dispatcher::new(None));
    let fc = d2.solver_fn(SolveOpts::default());
    let t0 = Instant::now();
    for s in &scales {
        let vals: Vec<f64> = nonsym.vals.iter().map(|v| v * s).collect();
        let _ = fc(&npat, &vals, &b, Transpose::Yes).unwrap();
    }
    let warm_lu = t0.elapsed().as_secs_f64();
    println!("\nchanging-values (Newton-shaped) adjoint solves, LU n=1500:");
    println!("  cold symbolic+numeric per step: {:8.1} ms", cold_lu * 1e3);
    println!("  symbolic reuse (refactor only): {:8.1} ms", warm_lu * 1e3);
    println!("  speedup: {:.1}x", cold_lu / warm_lu);

    assert!(
        speedup >= 2.0,
        "acceptance: repeated-adjoint-solve speedup must be >= 2x, got {speedup:.2}x"
    );
}
