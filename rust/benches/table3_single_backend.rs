//! Paper Table 3: single-device backend comparison on 2D Poisson.
//!
//! Columns map: SciPy(SuperLU) -> native-direct, cuDSS -> xla-direct,
//! paper's pytorch-CG -> xla-cg (fused PJRT artifact).  DOF scaled
//! ~100x down from the paper's H200 runs (this is a CPU container);
//! the SHAPE to reproduce: direct solvers win small & reach machine
//! precision, hit a memory wall as fill/n^2 grows, while CG scales
//! near-linearly (fit T ~ n^alpha, alpha ~ 1.1 in the paper) with
//! O(nnz) memory.
//!
//! Run: cargo bench --bench table3_single_backend

use rsla::backend::{Device, Dispatcher, Operator, Problem, SolveOpts};
use rsla::metrics::stopwatch::timed_median;
use rsla::runtime::RuntimeHandle;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::util::Prng;

struct Cell {
    text: String,
    secs: Option<f64>,
}

fn run_backend(
    d: &Dispatcher,
    sys: &rsla::sparse::poisson::PoissonSystem,
    b: &[f64],
    backend: &str,
    opts_base: &SolveOpts,
    reps: usize,
) -> (Cell, Option<(u64, f64, usize)>) {
    let opts = SolveOpts {
        backend: Some(backend.to_string()),
        ..opts_base.clone()
    };
    let p = Problem {
        op: Operator::Stencil(&sys.coeffs),
        b,
    };
    // pre-flight to classify errors without paying for retries
    match d.solve(&p, &opts) {
        Ok(first) => {
            let (out, secs) = timed_median(reps, || d.solve(&p, &opts).unwrap());
            let _ = first;
            (
                Cell {
                    text: fmt_time(secs),
                    secs: Some(secs),
                },
                Some((out.peak_bytes, out.residual, out.iters)),
            )
        }
        Err(rsla::Error::OutOfMemory { .. }) => (
            Cell {
                text: "OOM".into(),
                secs: None,
            },
            None,
        ),
        Err(_) => (
            Cell {
                text: "—".into(),
                secs: None,
            },
            None,
        ),
    }
}

fn fmt_time(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2} s")
    } else {
        format!("{:.1} ms", secs * 1e3)
    }
}

fn fmt_mem(bytes: u64) -> String {
    if bytes >= 1 << 30 {
        format!("{:.1} GB", bytes as f64 / 1e9)
    } else {
        format!("{:.1} MB", bytes as f64 / 1e6)
    }
}

fn main() {
    let runtime = RuntimeHandle::spawn_default().expect("run `make artifacts` first");
    let d = Dispatcher::new(Some(runtime));

    // host budget scaled so native-direct OOMs at the top size, like
    // SciPy at 16M DOF in the paper; accel budget per SolveOpts default.
    let opts = SolveOpts {
        device: Device::Accel,
        tol: 1e-7,
        max_iters: 200_000,
        host_mem_budget: 600 << 20,
        accel_mem_budget: 512 << 20,
        ..Default::default()
    };

    println!("# Table 3 (scaled): 2D Poisson, f64, variable-coefficient kappa*");
    println!("# native-direct = SciPy/SuperLU analog; xla-direct = cuDSS analog (PJRT dense Cholesky);");
    println!("# xla-cg = pytorch-native fused CG analog (Pallas SpMV in lax.while_loop, one PJRT call)");
    println!();
    println!(
        "| {:>7} | {:>10} | {:>10} | {:>10} | {:>9} | {:>9} | {:>8} |",
        "DOF", "direct", "xla-direct", "xla-cg", "Mem(cg)", "Resid(cg)", "iters"
    );
    println!("|---------|------------|------------|------------|-----------|-----------|----------|");

    let mut cg_points: Vec<(f64, f64)> = Vec::new();
    let mut cg_mem_per_dof = Vec::new();
    for &g in &[32usize, 64, 128, 256, 512] {
        let n = g * g;
        let kappa = kappa_star(g);
        let sys = poisson2d(g, Some(&kappa));
        let mut rng = Prng::new(g as u64);
        let b = rng.normal_vec(n);
        let reps = if n <= 20_000 { 5 } else { 3 };

        let (c_dir, _) = run_backend(&d, &sys, &b, "native-direct", &opts, reps);
        let (c_xd, _) = run_backend(&d, &sys, &b, "xla-direct", &opts, reps);
        let (c_cg, info) = run_backend(&d, &sys, &b, "xla-cg", &opts, reps);
        let (mem_s, res_s, iters_s) = match info {
            Some((mem, res, iters)) => {
                cg_mem_per_dof.push(mem as f64 / n as f64);
                if let Some(secs) = c_cg.secs {
                    cg_points.push((n as f64, secs));
                }
                (fmt_mem(mem), format!("{res:.0e}"), format!("{iters}"))
            }
            None => ("—".into(), "—".into(), "—".into()),
        };
        println!(
            "| {:>7} | {:>10} | {:>10} | {:>10} | {:>9} | {:>9} | {:>8} |",
            n, c_dir.text, c_xd.text, c_cg.text, mem_s, res_s, iters_s
        );
    }

    // fit T = c * n^alpha for the fused CG column (paper: alpha ~ 1.1)
    if cg_points.len() >= 3 {
        let logs: Vec<(f64, f64)> = cg_points.iter().map(|(n, t)| (n.ln(), t.ln())).collect();
        let m = logs.len() as f64;
        let sx: f64 = logs.iter().map(|p| p.0).sum();
        let sy: f64 = logs.iter().map(|p| p.1).sum();
        let sxx: f64 = logs.iter().map(|p| p.0 * p.0).sum();
        let sxy: f64 = logs.iter().map(|p| p.0 * p.1).sum();
        let alpha = (m * sxy - sx * sy) / (m * sxx - sx * sx);
        println!();
        println!("fused-CG scaling fit: T ~ n^{alpha:.2}   (paper: alpha ~ 1.1 incl. sqrt(kappa) growth)");
    }
    if !cg_mem_per_dof.is_empty() {
        let worst = cg_mem_per_dof.iter().cloned().fold(0.0, f64::max);
        println!("fused-CG memory: up to {worst:.0} B/DOF accounted (paper: 443 B/DOF measured, ~150 minimal)");
    }
}
