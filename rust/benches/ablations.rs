//! Ablations over the design choices DESIGN.md calls out:
//!
//!  A. preconditioner: Identity / Jacobi / SSOR / ILU(0) / IC(0) / AMG
//!     CG iterations (the paper ships Jacobi only and flags stronger
//!     preconditioners — explicitly AMG — as future work; this
//!     quantifies what that costs AND implements the future work);
//!  B. ordering: natural vs RCM vs random fill for envelope Cholesky;
//!  C. fused vs hybrid accelerator CG: the per-PJRT-call overhead the
//!     fused `lax.while_loop` artifact eliminates (cuDSS/cupy-vs-
//!     pytorch-native gap in Table 3);
//!  D. batching policy: coordinator service with/without the windowed
//!     pattern batcher;
//!  E. partition strategy: edge cut + halo volume, contiguous vs RCB
//!     vs BFS;
//!  F. reduction fusion: standard two-reduction distributed CG vs
//!     single-reduction (Chronopoulos–Gear, the Appendix C
//!     "pipelined/s-step" roadmap item) — reduction rounds per
//!     iteration and wall time.
//!
//! Run: cargo bench --bench ablations

use std::sync::Arc;

use rsla::backend::{Device, Dispatcher, Operator, Problem, SolveOpts};
use rsla::coordinator::{BatchPolicy, ServiceConfig, SolveService};
use rsla::direct::{ordering, EnvelopeCholesky};
use rsla::distributed::{
    dist_cg, dist_cg_pipelined, partition, run_ranks, DistIterOpts, PartitionStrategy,
};
use rsla::iterative::{cg, Amg, AmgOpts, Ic0, Identity, Ilu0, IterOpts, Jacobi, Precond, Ssor};
use rsla::metrics::stopwatch::timed_median;
use rsla::runtime::RuntimeHandle;
use rsla::sparse::poisson::poisson2d;
use rsla::util::Prng;

fn main() {
    ablation_preconditioner();
    ablation_ordering();
    ablation_fused_vs_hybrid();
    ablation_batching();
    ablation_partition();
    ablation_reduction_fusion();
}

fn ablation_preconditioner() {
    // variable-coefficient kappa*: constant-coefficient Poisson has a
    // constant diagonal, which makes Jacobi a no-op scaling.
    println!("# A. preconditioner ablation: CG on variable-coefficient 2D Poisson, tol 1e-8");
    println!(
        "| {:>7} | {:>10} | {:>10} | {:>10} | {:>10} | {:>10} | {:>10} |",
        "n", "identity", "jacobi", "ssor(1.5)", "ilu0", "ic0", "amg"
    );
    for &g in &[48usize, 96] {
        let kappa: Vec<f64> = {
            // rough 100x-contrast field: kappa* squared plus a bump
            rsla::sparse::poisson::kappa_star(g)
                .iter()
                .map(|k| k.powi(4))
                .collect()
        };
        let sys = poisson2d(g, Some(&kappa));
        let mut rng = Prng::new(g as u64);
        let b = rng.normal_vec(g * g);
        let opts = IterOpts {
            tol: 1e-8,
            max_iters: 100_000,
            record_history: false,
        };
        let run = |m: &dyn Precond| {
            let (r, secs) = timed_median(3, || cg(&sys.matrix, &b, m, &opts, None));
            assert!(r.converged);
            format!("{:>4} it {:>5.1}ms", r.iters, secs * 1e3)
        };
        let jac = Jacobi::new(&sys.matrix).unwrap();
        let ssor = Ssor::new(&sys.matrix, 1.5).unwrap();
        let ilu = Ilu0::new(&sys.matrix).unwrap();
        let ic = Ic0::new(&sys.matrix).unwrap();
        let amg = Amg::new(&sys.matrix, &AmgOpts::default()).unwrap();
        println!(
            "| {:>7} | {:>10} | {:>10} | {:>10} | {:>10} | {:>10} | {:>10} |",
            g * g,
            run(&Identity),
            run(&jac),
            run(&ssor),
            run(&ilu),
            run(&ic),
            run(&amg)
        );
    }
    // the multigrid signature: AMG-CG iterations stay flat as n grows
    println!("#    AMG iteration flatness (constant-coefficient Poisson):");
    for &g in &[32usize, 64, 128] {
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(7);
        let b = rng.normal_vec(g * g);
        let amg = Amg::new(&sys.matrix, &AmgOpts::default()).unwrap();
        let jac = Jacobi::new(&sys.matrix).unwrap();
        let opts = IterOpts {
            tol: 1e-8,
            max_iters: 100_000,
            record_history: false,
        };
        let ra = cg(&sys.matrix, &b, &amg, &opts, None);
        let rj = cg(&sys.matrix, &b, &jac, &opts, None);
        println!(
            "#      n={:>6}: amg {:>3} it (levels={}, opcx={:.2})  jacobi {:>4} it",
            g * g,
            ra.iters,
            amg.n_levels(),
            amg.operator_complexity(),
            rj.iters
        );
    }
    println!();
}

fn ablation_ordering() {
    println!("# B. ordering ablation: envelope Cholesky fill (f64 count)");
    println!(
        "| {:>7} | {:>12} | {:>12} | {:>12} |",
        "n", "natural", "rcm", "shuffled"
    );
    for &g in &[24usize, 48] {
        let sys = poisson2d(g, None);
        let natural = EnvelopeCholesky::predicted_fill(&sys.matrix);
        let p = ordering::rcm(&sys.matrix);
        let rcm_fill = EnvelopeCholesky::predicted_fill(&sys.matrix.permute_sym(&p));
        let mut rng = Prng::new(0);
        let mut shuf: Vec<usize> = (0..g * g).collect();
        rng.shuffle(&mut shuf);
        let shuffled = EnvelopeCholesky::predicted_fill(&sys.matrix.permute_sym(&shuf));
        println!(
            "| {:>7} | {:>12} | {:>12} | {:>12} |",
            g * g,
            natural,
            rcm_fill,
            shuffled
        );
    }
    println!();
}

fn ablation_fused_vs_hybrid() {
    println!("# C. fused (one PJRT call) vs hybrid (one PJRT call PER ITERATION)");
    let runtime = match RuntimeHandle::spawn_default() {
        Ok(r) => r,
        Err(e) => {
            println!("skipped (no artifacts: {e})\n");
            return;
        }
    };
    // per-call overhead probe
    let probe = {
        let x = vec![1.0; 65536];
        let args = [
            rsla::runtime::Arg::vec(x.clone()),
            rsla::runtime::Arg::vec(x),
        ];
        let _ = runtime.run("dot_n65536", &args); // warm the compile cache
        let (_, secs) = timed_median(20, || runtime.run("dot_n65536", &args).unwrap());
        secs
    };
    println!("per-PJRT-call overhead (dot_n65536 probe): {:.0} us", probe * 1e6);

    let d = Dispatcher::new(Some(runtime));
    println!(
        "| {:>7} | {:>12} | {:>12} | {:>7} | {:>10} |",
        "n", "fused", "hybrid", "iters", "gap"
    );
    for &g in &[32usize, 64, 128] {
        let sys = poisson2d(g, None);
        let mut rng = Prng::new(g as u64);
        let b = rng.normal_vec(g * g);
        let p = Problem {
            op: Operator::Stencil(&sys.coeffs),
            b: &b,
        };
        let mk = |backend: &str| SolveOpts {
            device: Device::Accel,
            backend: Some(backend.into()),
            tol: 1e-8,
            ..Default::default()
        };
        let (fused, t_f) = timed_median(3, || d.solve(&p, &mk("xla-cg")).unwrap());
        let (hybrid, t_h) = timed_median(3, || d.solve(&p, &mk("xla-hybrid")).unwrap());
        println!(
            "| {:>7} | {:>9.1} ms | {:>9.1} ms | {:>7} | {:>9.1}x |",
            g * g,
            t_f * 1e3,
            t_h * 1e3,
            hybrid.iters,
            t_h / t_f
        );
        let _ = fused;
    }
    println!();
}

fn ablation_batching() {
    println!("# D. batching policy: 64 shared-pattern requests through the service");
    for (label, window_ms, max_batch) in
        [("no batching", 0u64, 1usize), ("2ms window x32", 2, 32)]
    {
        let svc = SolveService::start(
            Arc::new(Dispatcher::new(None)),
            ServiceConfig {
                workers: 2,
                batch: BatchPolicy {
                    max_batch,
                    window: std::time::Duration::from_millis(window_ms),
                },
            },
        );
        let sys = poisson2d(32, None);
        let mut rng = Prng::new(1);
        let t0 = std::time::Instant::now();
        let rxs: Vec<_> = (0..64)
            .map(|_| {
                svc.submit(
                    sys.matrix.clone(),
                    rng.normal_vec(sys.matrix.nrows),
                    SolveOpts::default(),
                )
            })
            .collect();
        let mut batched = 0;
        for rx in rxs {
            let r = rx.recv().unwrap();
            r.outcome.unwrap();
            if r.batch_size > 1 {
                batched += 1;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        println!(
            "  {label:<16} total {:>7.1} ms  ({:>5.0} req/s), {batched}/64 batched",
            wall * 1e3,
            64.0 / wall
        );
    }
    println!();
}

fn ablation_partition() {
    println!("# E. partition strategy: edge cut + max halo, g=64 grid, P=4");
    let sys = poisson2d(64, None);
    for (name, strat) in [
        ("contiguous", PartitionStrategy::Contiguous),
        ("rcb", PartitionStrategy::Rcb),
        ("greedy-bfs", PartitionStrategy::GreedyBfs),
    ] {
        let part = partition::partition(&sys.matrix, Some(&sys.coords), 4, strat);
        let ap = sys.matrix.permute_sym(&part.perm);
        let cut = part.edge_cut(&ap);
        let shares = rsla::distributed::halo::distribute(&ap, &part);
        let halo = shares.iter().map(|s| s.plan.n_halo()).max().unwrap();
        println!("  {name:<12} edge-cut {cut:>6}   max halo {halo:>5}");
    }
    println!();
}

fn ablation_reduction_fusion() {
    println!("# F. reduction fusion: 2-reduction CG vs single-reduction (pipelined) CG, P=4");
    println!(
        "| {:>7} | {:>9} | {:>9} | {:>12} | {:>12} | {:>9} |",
        "n", "std it", "pip it", "std reds/it", "pip reds/it", "time gap"
    );
    for &g in &[48usize, 96] {
        let sys = poisson2d(g, Some(&rsla::sparse::poisson::kappa_star(g)));
        let nparts = 4;
        let part = partition::partition(
            &sys.matrix,
            Some(&sys.coords),
            nparts,
            PartitionStrategy::Rcb,
        );
        let a_perm = sys.matrix.permute_sym(&part.perm);
        let parts = Arc::new(rsla::distributed::halo::distribute(&a_perm, &part));
        let part = Arc::new(part);
        let mut rng = Prng::new(g as u64);
        let b = Arc::new(rng.normal_vec(g * g));
        let opts = DistIterOpts {
            tol: 1e-9,
            max_iters: 100_000,
                ..Default::default()
            };

        let run = |pipelined: bool| {
            let (bc, p2, ps, o) = (b.clone(), part.clone(), parts.clone(), opts.clone());
            let t0 = std::time::Instant::now();
            let out = run_ranks(nparts, move |c| {
                let p = c.rank();
                let range = p2.rank_range(p);
                let rep = if pipelined {
                    dist_cg_pipelined(&ps[p], &bc[range], &c, &o)
                } else {
                    dist_cg(&ps[p], &bc[range], &c, &o)
                };
                (rep.iters, rep.converged, c.reduce_rounds())
            });
            let wall = t0.elapsed().as_secs_f64();
            assert!(out.iter().all(|(_, conv, _)| *conv));
            (out[0].0, out[0].2 as f64 / out[0].0 as f64, wall)
        };
        let (it_s, red_s, t_s) = run(false);
        let (it_p, red_p, t_p) = run(true);
        println!(
            "| {:>7} | {:>9} | {:>9} | {:>12.2} | {:>12.2} | {:>8.2}x |",
            g * g,
            it_s,
            it_p,
            red_s,
            red_p,
            t_s / t_p
        );
    }
}
