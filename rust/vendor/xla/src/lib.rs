//! Vendored stub of the `xla` (xla-rs / PJRT) bindings.
//!
//! The real crate needs the XLA C++ runtime and network access to
//! build, neither of which exists in this container.  This stub keeps
//! the exact API surface `rsla::runtime` compiles against and *gates*
//! the missing dependency at runtime: `PjRtClient::cpu()` fails with a
//! descriptive error, so `Registry::open` / `RuntimeHandle::spawn`
//! degrade exactly the way a missing `artifacts/` directory does — the
//! dispatcher falls back to the native backends and everything else
//! keeps working.
//!
//! Swapping the real bindings back in is a one-line Cargo.toml change;
//! no rsla source references this stub directly.

use std::fmt;

/// Error type mirroring `xla::Error` far enough for `e.to_string()`.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable() -> Error {
    Error("xla runtime not available in this build (vendored stub; see rust/vendor/xla)".into())
}

/// Element types the stub literal constructors accept.
pub trait NativeType: Copy {}
impl NativeType for f64 {}
impl NativeType for f32 {}
impl NativeType for i32 {}
impl NativeType for i64 {}

/// Opaque literal; carries no data in the stub.
#[derive(Clone, Debug, Default)]
pub struct Literal;

impl Literal {
    pub fn vec1<T: NativeType>(_values: &[T]) -> Literal {
        Literal
    }

    pub fn scalar<T: NativeType>(_value: T) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable())
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(unavailable())
    }
}

/// Device-side buffer handle returned by executions.
#[derive(Clone, Debug, Default)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable())
    }
}

/// PJRT client handle.  `cpu()` is the single gate point: it fails in
/// the stub, so nothing downstream ever executes.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable())
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable())
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable())
    }
}

/// Parsed HLO module proto.
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable())
    }
}

/// XLA computation wrapper.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_is_gated() {
        assert!(PjRtClient::cpu().is_err());
        let msg = PjRtClient::cpu().unwrap_err().to_string();
        assert!(msg.contains("stub"));
    }

    #[test]
    fn literal_constructors_are_total() {
        let l = Literal::vec1(&[1.0f64, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f64>().is_err());
        let _ = Literal::scalar(3i32);
    }
}
