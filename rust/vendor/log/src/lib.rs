//! Minimal vendored stand-in for the `log` crate.
//!
//! The container this repo builds in has no network access and no
//! vendored registry, so the real `log` facade cannot be pulled in.
//! This stub provides the macro surface rsla uses (`warn!`, `debug!`,
//! `info!`, `error!`, `trace!`).  `warn!`/`error!` go to stderr (they
//! mark degraded-but-working paths, e.g. "PJRT runtime unavailable");
//! the rest only evaluate their arguments.

/// Log level marker (API-compatible subset; unused by the stub macros).
#[derive(Copy, Clone, Debug, PartialEq, Eq)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
    Trace,
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => {
        eprintln!("[error] {}", format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => {
        eprintln!("[warn] {}", format!($($arg)*))
    };
}

// The low-severity macros must be true no-ops on the hot path (the
// dispatcher debug-logs every refused candidate): the never-called
// closure type-checks and "uses" the arguments without evaluating or
// allocating anything at runtime.

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => {{
        let _ = || format!($($arg)*);
    }};
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => {{
        let _ = || format!($($arg)*);
    }};
}

#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => {{
        let _ = || format!($($arg)*);
    }};
}
