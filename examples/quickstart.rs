//! Quickstart: the paper's Listing 1 in rsla form.
//!
//! 1. build a sparse matrix (2D Poisson),
//! 2. `.solve(b)` with auto-dispatch,
//! 3. differentiate a loss through the solve (adjoint, O(1) graph),
//! 4. verify the gradient against finite differences.
//!
//! Run: cargo run --release --example quickstart

use rsla::autograd::Tape;
use rsla::backend::SolveOpts;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::tensor::SparseTensor;
use rsla::util::{self, Prng};

fn main() {
    // --- 1. assemble: -div(kappa grad u) = b on a 48x48 grid ---
    let g = 48;
    let n = g * g;
    let kappa = kappa_star(g);
    let sys = poisson2d(g, Some(&kappa));
    let a = SparseTensor::from_csr(sys.matrix.clone());
    println!("A: {}x{} with {} non-zeros", a.nrows(), a.nrows(), a.nnz());

    // --- 2. solve with auto-dispatch ---
    let mut rng = Prng::new(0);
    let b = rng.normal_vec(n);
    let out = a.solve_full(0, &b, &SolveOpts::default()).unwrap();
    println!(
        "solve: backend={} method={} residual={:.2e}",
        out.backend, out.method, out.residual
    );
    assert!(util::rel_l2(&sys.matrix.matvec(&out.x), &b) < 1e-8);

    // --- 3. differentiate loss = ||x||^2 through the solve ---
    let tape = Tape::new();
    let vals = tape.leaf_vec(sys.matrix.vals.clone());
    let bv = tape.leaf_vec(b.clone());
    let x = a.solve_ad(&tape, vals, bv, &SolveOpts::default()).unwrap();
    let loss = tape.dot(x, x);
    println!(
        "autograd: loss = {:.6}, graph nodes = {} (O(1) per solve)",
        tape.scalar_of(loss),
        tape.node_count()
    );
    let grads = tape.backward(loss);
    let db = grads.vec(bv).clone();
    let dvals = grads.vec(vals).clone();
    println!(
        "gradients: |dL/db| = {:.3e}, |dL/dA| = {:.3e} ({} entries, O(nnz))",
        util::norm2(&db),
        util::norm2(&dvals),
        dvals.len()
    );

    // --- 4. finite-difference check on dL/db ---
    let loss_of_b = |bb: &[f64]| {
        let x = a.solve(bb, &SolveOpts::default()).unwrap();
        util::dot(&x, &x)
    };
    let check = rsla::gradcheck::check_direction(loss_of_b, &b, &db, 1e-6, 3, 42);
    println!(
        "gradcheck vs central FD: rel error {:.2e} (paper Table 5 band: < 1e-5)",
        check.rel_error
    );
    assert!(check.rel_error < 1e-5);
    println!("quickstart OK");
}
