//! Batched solves — both flavors of paper §3.1:
//!
//! * shared pattern (`SparseTensor` with a batch of value planes /
//!   multi-RHS): one symbolic factorization serves the whole batch;
//! * distinct patterns (`SparseTensorList`, the GNN-minibatch case):
//!   per-element dispatch with isolated autograd graphs;
//!
//! plus the coordinator's windowed batcher serving a mixed request
//! stream (the "training step with one sparse system per sample").
//!
//! Run: cargo run --release --example batched_graphs

use std::sync::Arc;

use rsla::autograd::Tape;
use rsla::backend::{Dispatcher, SolveOpts};
use rsla::coordinator::{ServiceConfig, SolveService};
use rsla::sparse::graphs::random_graph_laplacian;
use rsla::sparse::poisson::poisson2d;
use rsla::sparse::Pattern;
use rsla::tensor::{SparseTensor, SparseTensorList};
use rsla::util::{self, Prng};

fn main() {
    let mut rng = Prng::new(42);

    // --- shared-pattern batch: 8 scaled Poisson operators ---
    let sys = poisson2d(24, None);
    let pattern = Pattern::of(&sys.matrix);
    let scales: Vec<f64> = (0..8).map(|i| 0.5 + 0.25 * i as f64).collect();
    let vals: Vec<Vec<f64>> = scales
        .iter()
        .map(|s| sys.matrix.vals.iter().map(|v| v * s).collect())
        .collect();
    let batch = SparseTensor::batched(pattern, vals).unwrap();
    let bs: Vec<Vec<f64>> = (0..8).map(|_| rng.normal_vec(576)).collect();
    let t0 = std::time::Instant::now();
    let xs = batch.solve_batch(&bs, &SolveOpts::default()).unwrap();
    println!(
        "shared-pattern batch: 8 solves (n=576) in {:.1} ms",
        t0.elapsed().as_secs_f64() * 1e3
    );
    for ((x, b), s) in xs.iter().zip(&bs).zip(&scales) {
        let mut ax = sys.matrix.matvec(x);
        for v in ax.iter_mut() {
            *v *= s;
        }
        assert!(util::rel_l2(&ax, b) < 1e-8);
    }

    // --- distinct patterns: GNN-style minibatch of graph Laplacians ---
    let mats: Vec<_> = (0..6)
        .map(|i| random_graph_laplacian(&mut rng, 80 + 40 * i, 4, 0.3))
        .collect();
    let list = SparseTensorList::from_csrs(mats.clone());
    let bs: Vec<Vec<f64>> = mats.iter().map(|m| rng.normal_vec(m.nrows)).collect();
    let t1 = std::time::Instant::now();
    let outs = list.solve_full(&bs, &SolveOpts::default()).unwrap();
    println!(
        "\ndistinct-pattern list: {} graphs (n=80..280) in {:.1} ms",
        list.len(),
        t1.elapsed().as_secs_f64() * 1e3
    );
    for (out, (m, b)) in outs.iter().zip(mats.iter().zip(&bs)) {
        println!(
            "  n={:<4} backend={} method={} residual={:.1e}",
            m.nrows, out.backend, out.method, out.residual
        );
        assert!(util::rel_l2(&m.matvec(&out.x), b) < 1e-7);
    }

    // --- differentiable batch: gradient through every element ---
    let tape = Tape::new();
    let vals_vars: Vec<_> = mats.iter().map(|m| tape.leaf_vec(m.vals.clone())).collect();
    let b_vars: Vec<_> = bs.iter().map(|b| tape.leaf_vec(b.clone())).collect();
    let xs = list
        .solve_ad(&tape, &vals_vars, &b_vars, &SolveOpts::default())
        .unwrap();
    // joint loss = sum of per-graph energies
    let mut loss = tape.dot(xs[0], xs[0]);
    for x in &xs[1..] {
        let li = tape.dot(*x, *x);
        loss = tape.add_ss(loss, li);
    }
    let grads = tape.backward(loss);
    println!(
        "\nautograd through the batch: {} nodes for {} solves (O(1) each)",
        tape.node_count() - 2 * mats.len(), // minus the leaves
        mats.len()
    );
    for v in &vals_vars {
        assert!(grads.vec(*v).iter().any(|x| *x != 0.0));
    }

    // --- coordinator service on a bursty mixed stream ---
    let svc = SolveService::start(Arc::new(Dispatcher::new(None)), ServiceConfig::default());
    let shared = poisson2d(20, None).matrix;
    let t2 = std::time::Instant::now();
    let mut rxs = Vec::new();
    for i in 0..48 {
        let (a, b) = if i % 3 != 0 {
            (shared.clone(), rng.normal_vec(shared.nrows))
        } else {
            let a = random_graph_laplacian(&mut rng, 120, 4, 0.3);
            let b = rng.normal_vec(120);
            (a, b)
        };
        rxs.push(svc.submit(a, b, SolveOpts::default()));
    }
    let mut batched = 0;
    for rx in rxs {
        let resp = rx.recv().unwrap();
        resp.outcome.unwrap();
        if resp.batch_size > 1 {
            batched += 1;
        }
    }
    println!(
        "\nservice: 48 requests in {:.1} ms, {batched} rode shared-pattern batches",
        t2.elapsed().as_secs_f64() * 1e3
    );
    svc.shutdown();
    println!("\nbatched_graphs OK");
}
