//! END-TO-END VALIDATION DRIVER — the paper's Fig. 3 inverse problem.
//!
//! Learn the conductivity field kappa(x) of
//!     -div(kappa grad u) = 1  on (0,1)^2,  u = 0 on the boundary
//! from observations of u alone, on a 64x64 grid, by differentiating
//! THROUGH the sparse solve with the adjoint framework:
//!
//!     theta --softplus--> kappa --assembly--> A(kappa) --solve--> u
//!     loss = ||u - u_obs||^2 + 1e-3 * ||grad_h kappa||^2 / N
//!
//! Every step: Adam(lr = 5e-2) on theta; the only solver-specific call
//! is `solve_linear` (the paper's `A.solve(f)`).  Paper results to match
//! in shape: monotone loss decrease, kappa rel-L2 error ~2.3e-3 after
//! 1500 steps, recovered range ~[0.503, 1.495].
//!
//! Run: cargo run --release --example inverse_coefficient [STEPS]

use rsla::autograd::Tape;
use rsla::backend::SolveOpts;
use rsla::optim::Adam;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::tensor::PoissonAssembler;
use rsla::util;

fn main() {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(1500);
    let g = 64;
    let n = g * g;
    let asm = PoissonAssembler::new(g);

    // ground truth + observations
    let kappa_true = kappa_star(g);
    let sys_true = poisson2d(g, Some(&kappa_true));
    let f_rhs = vec![1.0; n];
    let u_obs = rsla::direct::direct_solve(&sys_true.matrix, &f_rhs).expect("forward solve");

    // theta = softplus^{-1}(1.0): start from constant kappa = 1
    let theta0 = (1.0f64.exp() - 1.0).ln();
    let mut theta = vec![theta0; n];
    let mut adam = Adam::new(n, 5e-2);
    let opts = SolveOpts {
        tol: 1e-11,
        ..Default::default()
    };
    let solver = rsla::tensor::SparseTensor::from_csr(sys_true.matrix.clone()).solver_fn(opts);

    println!("# step  loss  kappa_rel_l2  u_rel_l2");
    let t0 = std::time::Instant::now();
    let mut final_kappa = vec![0.0; n];
    for step in 0..steps {
        let tape = Tape::new();
        let th = tape.leaf_vec(theta.clone());
        let kappa = tape.softplus(th);
        let vals = asm.assemble(&tape, kappa);
        let b = tape.constant_vec(f_rhs.clone());
        let u = rsla::adjoint::solve_linear(&tape, &asm.pattern, vals, b, &solver).expect("solve");
        // data term ||u - u_obs||^2
        let uo = tape.constant_vec(u_obs.clone());
        let diff = tape.sub(u, uo);
        let data = tape.dot(diff, diff);
        // Tikhonov smoothness 1e-3 * ||grad_h kappa||^2 / N
        let reg = asm.smoothness(&tape, kappa);
        let reg_scaled = tape.scale_const_s(1e-3, reg);
        let loss = tape.add_ss(data, reg_scaled);

        let grads = tape.backward(loss);
        let gtheta = grads.vec(th).clone();
        adam.step(&mut theta, &gtheta);

        if step % 100 == 0 || step + 1 == steps {
            let kv = tape.vec_of(kappa);
            let k_err = util::rel_l2(&kv, &kappa_true);
            let uv = tape.vec_of(u);
            let u_err = util::rel_l2(&uv, &u_obs);
            println!(
                "{step:5}  {:.6e}  {:.3e}  {:.3e}",
                tape.scalar_of(loss),
                k_err,
                u_err
            );
            final_kappa = kv;
        }
    }
    let secs = t0.elapsed().as_secs_f64();

    let k_err = util::rel_l2(&final_kappa, &kappa_true);
    let lo = final_kappa.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = final_kappa
        .iter()
        .cloned()
        .fold(f64::NEG_INFINITY, f64::max);
    let max_pt = final_kappa
        .iter()
        .zip(&kappa_true)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0, f64::max);
    println!("\n== inverse coefficient learning (paper Fig. 3) ==");
    println!(
        "steps           {steps} ({:.1} s, {:.1} ms/step)",
        secs,
        secs * 1e3 / steps as f64
    );
    println!("kappa rel-L2    {k_err:.3e}   (paper: 2.3e-3 @ 1500 steps)");
    println!("kappa range     [{lo:.3}, {hi:.3}]   (paper: [0.503, 1.495], truth [0.5, 1.5])");
    println!("max |k - k*|    {max_pt:.3e}   (paper: < 1.1e-2)");
    // convergence gate only for full-length runs (short runs are smoke tests)
    if steps >= 1000 {
        assert!(k_err < 0.01, "recovery failed: rel err {k_err}");
    }
    println!("OK");
}
