//! Learned preconditioner trained END-TO-END through sparse solves —
//! the paper's closing vision (§5): "registering learned preconditioners
//! ... trained end-to-end against full sparse solves — making torch-sla a
//! substrate for learnable sparse solvers at scale".
//!
//! We learn the coefficients of a degree-d polynomial preconditioner
//! M^{-1} = sum_k c_k (D^{-1} A)^k D^{-1} for the variable-coefficient
//! Poisson operator.  The training loss is the TRUE objective — the
//! preconditioned residual after a fixed number of Richardson steps —
//! and every gradient flows through sparse matvecs on the autograd tape
//! (O(1) nodes per op, O(nnz) memory), exactly the machinery the adjoint
//! framework provides.  After training, the learned polynomial is wrapped
//! as a [`Precond`] and dropped into the production CG loop, where it is
//! compared against Jacobi on iteration count.
//!
//! Run: cargo run --release --example learned_preconditioner

use rsla::autograd::{naive_cg::TapeSpmv, Tape, Var};
use rsla::iterative::{cg, IterOpts, Jacobi, Precond};
use rsla::sparse::Pattern;
use rsla::optim::Adam;
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::sparse::Csr;
use rsla::util::{self, Prng};
use std::sync::Arc;

/// Polynomial preconditioner z = sum_k c_k (D^{-1} A)^k D^{-1} r.
struct PolyPrecond {
    a: Csr,
    inv_diag: Vec<f64>,
    coeffs: Vec<f64>,
}

impl Precond for PolyPrecond {
    fn apply(&self, r: &[f64], z: &mut [f64]) {
        let n = r.len();
        // t = D^{-1} r
        let mut t: Vec<f64> = r.iter().zip(&self.inv_diag).map(|(r, d)| r * d).collect();
        for zi in z.iter_mut() {
            *zi = 0.0;
        }
        let mut tmp = vec![0.0; n];
        for (k, c) in self.coeffs.iter().enumerate() {
            if k > 0 {
                // t <- D^{-1} A t
                self.a.spmv(&t, &mut tmp);
                for i in 0..n {
                    t[i] = tmp[i] * self.inv_diag[i];
                }
            }
            for i in 0..n {
                z[i] += c * t[i];
            }
        }
    }
}

/// Tape-side application of the same polynomial: returns the Var for
/// z(c) = sum_k c_k (D^{-1}A)^k D^{-1} r with gradients w.r.t. c.
#[allow(clippy::too_many_arguments)]
fn poly_apply_ad(
    tape: &Tape,
    spmv: &TapeSpmv,
    avals: Var,
    inv_diag: &Arc<Vec<f64>>,
    c: &[Var],
    r: Var,
) -> Var {
    // t_0 = D^{-1} r
    let mut t = tape.mul_const_vec(inv_diag.clone(), r);
    let mut acc = tape.mul_sv(c[0], t);
    for ck in c.iter().skip(1) {
        // t <- D^{-1} (A t)
        let at = spmv.apply(tape, avals, t);
        t = tape.mul_const_vec(inv_diag.clone(), at);
        let term = tape.mul_sv(*ck, t);
        acc = tape.add(acc, term);
    }
    acc
}

fn main() {
    let g = 48;
    let n = g * g;
    let sys = poisson2d(g, Some(&kappa_star(g)));
    let a = sys.matrix.clone();
    let inv_diag: Arc<Vec<f64>> = Arc::new(
        a.diag()
            .iter()
            .map(|d| if *d != 0.0 { 1.0 / d } else { 1.0 })
            .collect(),
    );

    let degree = 4usize;
    // init: c = [1, 0, 0, 0] == plain Jacobi
    let mut theta = vec![0.0_f64; degree];
    theta[0] = 1.0;
    let mut adam = Adam::new(degree, 2e-2);
    let mut rng = Prng::new(0);

    println!("== learned polynomial preconditioner (degree {degree}) ==");
    println!("train: minimize || r - A M^-1(c) r ||^2 / ||r||^2 over random residuals\n");

    let pattern = Pattern::of(&a);
    let spmv = TapeSpmv::new(&pattern);
    let steps = 400;
    let mut last = 0.0;
    for step in 0..steps {
        let r0 = rng.normal_vec(n);
        let tape = Tape::new();
        let cvars: Vec<Var> = theta.iter().map(|t| tape.leaf_scalar(*t)).collect();
        let rv = tape.constant_vec(r0.clone());
        let avals = tape.constant_vec(a.vals.clone());
        // z = M^{-1}(c) r ; residual of the preconditioner as an A^{-1}
        // approximation: e = r - A z
        let z = poly_apply_ad(&tape, &spmv, avals, &inv_diag, &cvars, rv);
        let az = spmv.apply(&tape, avals, z);
        let e = tape.sub(rv, az);
        let num = tape.dot(e, e);
        let den = util::dot(&r0, &r0);
        let loss = tape.scale_const_s(1.0 / den, num);
        last = tape.scalar_of(loss);
        let grads = tape.backward(loss);
        let dtheta: Vec<f64> = cvars
            .iter()
            .map(|v| grads.get(*v).map(|g| g.as_scalar()).unwrap_or(0.0))
            .collect();
        adam.step(&mut theta, &dtheta);
        if step % 100 == 0 || step == steps - 1 {
            println!("  step {step:>4}: loss {last:.4e}   c = {theta:.4?}");
        }
    }

    // drop the learned polynomial into the production CG loop
    let learned = PolyPrecond {
        a: a.clone(),
        inv_diag: inv_diag.to_vec(),
        coeffs: theta.clone(),
    };
    let jacobi = Jacobi::new(&a).unwrap();
    let b = rng.normal_vec(n);
    let opts = IterOpts {
        tol: 1e-9,
        max_iters: 50_000,
        record_history: false,
    };
    let r_jac = cg(&a, &b, &jacobi, &opts, None);
    let r_lrn = cg(&a, &b, &learned, &opts, None);
    assert!(r_jac.converged && r_lrn.converged);
    assert!(util::rel_l2(&a.matvec(&r_lrn.x), &b) < 1e-7);
    println!("\n== production CG with the learned preconditioner ==");
    println!("  jacobi : {:>4} iterations", r_jac.iters);
    println!(
        "  learned: {:>4} iterations  ({:.2}x fewer; degree-{degree} polynomial, {} spmv/apply)",
        r_lrn.iters,
        r_jac.iters as f64 / r_lrn.iters as f64,
        degree - 1
    );
    // each learned apply costs (degree-1) extra SpMVs; report the
    // matvec-normalized comparison the paper's reviewers would ask for
    let mv_jac = r_jac.iters; // 1 spmv per iteration
    let mv_lrn = r_lrn.iters * degree; // 1 + (degree-1) per iteration
    println!(
        "  total SpMVs: jacobi {mv_jac} vs learned {mv_lrn}  ({})",
        if mv_lrn < mv_jac {
            "learned wins even matvec-normalized"
        } else {
            "jacobi cheaper per-matvec; learned wins on latency-bound iterations"
        }
    );
    assert!(
        (r_lrn.iters as f64) < 0.67 * r_jac.iters as f64,
        "learned preconditioner should cut iterations by >1.5x: {} vs {}",
        r_lrn.iters,
        r_jac.iters
    );
    println!("\nlearned_preconditioner OK");
}
