//! Distributed solve + distributed autograd (paper §3.3, Table 4 scaled
//! down): partition a 2D Poisson system over P in-process ranks, run
//! distributed Jacobi-CG with halo exchange, then the distributed
//! adjoint (transposed halo exchange) and verify gradients against the
//! serial adjoint.
//!
//! Run: cargo run --release --example distributed_poisson [G] [RANKS]

use rsla::distributed::{DSparseTensor, DistIterOpts, PartitionStrategy};
use rsla::sparse::poisson::{kappa_star, poisson2d};
use rsla::util::{self, Prng};

fn main() {
    let g: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(96);
    let ranks: usize = std::env::args()
        .nth(2)
        .and_then(|s| s.parse().ok())
        .unwrap_or(4);
    let n = g * g;
    println!("2D Poisson g={g} (n={n}), {ranks} ranks, RCB partition\n");

    let kappa = kappa_star(g);
    let sys = poisson2d(g, Some(&kappa));
    let dt = DSparseTensor::from_global(
        &sys.matrix,
        Some(&sys.coords),
        ranks,
        PartitionStrategy::Rcb,
    )
    .expect("partition");

    // --- distributed forward solve ---
    let mut rng = Prng::new(0);
    let b = rng.normal_vec(n);
    let t0 = std::time::Instant::now();
    let (x, reports) = dt.solve(&b, &DistIterOpts::default()).unwrap();
    let secs = t0.elapsed().as_secs_f64();
    let res = util::rel_l2(&sys.matrix.matvec(&x), &b);
    println!(
        "forward dist-CG: iters={} rel-residual={:.2e} time={:.1} ms ({:.2} MDOF/s)",
        reports[0].iters,
        res,
        secs * 1e3,
        n as f64 / secs / 1e6
    );
    for (p, r) in reports.iter().enumerate() {
        println!(
            "  rank {p}: mem {:>8.1} KB ({:.0} B/DOF)   sent {:>8.1} KB",
            r.peak_bytes as f64 / 1e3,
            r.peak_bytes as f64 / (n as f64 / ranks as f64),
            r.bytes_sent as f64 / 1e3,
        );
    }
    assert!(res < 1e-8);

    // --- distributed adjoint: dL/db and dL/dA for L = <w, x> ---
    let w = rng.normal_vec(n);
    let t1 = std::time::Instant::now();
    let (x2, db, dvals) = dt
        .solve_adjoint(&b, &w, &DistIterOpts::default())
        .unwrap();
    let adj_secs = t1.elapsed().as_secs_f64();
    // serial reference
    let x_ref = rsla::direct::direct_solve(&sys.matrix, &b).unwrap();
    let lam_ref = rsla::direct::direct_solve(&sys.matrix, &w).unwrap();
    println!(
        "\nadjoint (fwd+bwd dist-CG + local O(nnz) assembly): {:.1} ms",
        adj_secs * 1e3
    );
    println!("  x  vs serial: rel err {:.2e}", util::rel_l2(&x2, &x_ref));
    println!("  db vs serial: rel err {:.2e}", util::rel_l2(&db, &lam_ref));
    let mut worst = 0.0f64;
    for &(r, c, v) in dvals.iter() {
        let want = -lam_ref[r] * x_ref[c];
        worst = worst.max((v - want).abs() / (1.0 + want.abs()));
    }
    println!("  dA vs -lambda_i x_j: worst rel err {worst:.2e} over {} entries", dvals.len());
    assert!(util::rel_l2(&db, &lam_ref) < 1e-5 && worst < 1e-5);

    // --- distributed eigsh vs serial LOBPCG (same algorithm) ---
    let vals = dt.eigsh(3, 1e-7, 600).unwrap();
    let m = rsla::iterative::Jacobi::new(&sys.matrix).unwrap();
    let serial = rsla::eigen::lobpcg(
        &sys.matrix,
        &m,
        3,
        &rsla::eigen::LobpcgOpts {
            tol: 1e-7,
            max_iters: 600,
            seed: 0,
        },
    );
    println!("\ndist-LOBPCG smallest eigenvalues vs serial LOBPCG:");
    for (a, b) in vals.iter().zip(&serial.values) {
        println!("  {a:.6}  vs  {b:.6}");
        assert!((a - b).abs() < 1e-3 * b, "{a} vs {b}");
    }
    println!("\ndistributed_poisson OK");
}
