//! Nonlinear and eigenvalue adjoints (paper §3.2.2 / Table 5).
//!
//! * Nonlinear: solve A u + u^2 = f by Newton; gradient of <w, u> with
//!   respect to f via ONE adjoint solve (not 5), checked against
//!   central finite differences.
//! * Eigenvalue: k = 6 smallest eigenvalues of a graph Laplacian via
//!   LOBPCG; Hellmann–Feynman gradient (outer product on the pattern,
//!   NO extra solve), checked against finite differences.
//!
//! Run: cargo run --release --example nonlinear_eigen

use std::rc::Rc;

use rsla::adjoint::{eigsh, solve_nonlinear};
use rsla::autograd::Tape;
use rsla::eigen::LobpcgOpts;
use rsla::nonlinear::{newton, NewtonOpts, Residual};
use rsla::sparse::graphs::random_graph_laplacian;
use rsla::sparse::poisson::{poisson2d, PoissonSystem};
use rsla::sparse::{Coo, Csr, Pattern};
use rsla::util::{dot, Prng};

/// F(u; f) = A u + u^2 - f (the paper's example nonlinearity).
struct QuadPoisson {
    sys: PoissonSystem,
    f: Vec<f64>,
}

impl Residual for QuadPoisson {
    fn dim(&self) -> usize {
        self.f.len()
    }
    fn eval(&self, u: &[f64], out: &mut [f64]) {
        self.sys.matrix.spmv(u, out);
        for i in 0..u.len() {
            out[i] += u[i] * u[i] - self.f[i];
        }
    }
    fn jacobian(&self, u: &[f64]) -> Csr {
        let a = &self.sys.matrix;
        let n = a.nrows;
        let mut coo = Coo::with_capacity(n, n, a.nnz() + n);
        for r in 0..n {
            let (cols, vals) = a.row(r);
            for (c, v) in cols.iter().zip(vals) {
                coo.push(r, *c, *v);
            }
            coo.push(r, r, 2.0 * u[r]);
        }
        coo.to_csr()
    }
    fn vjp_theta(&self, _u: &[f64], w: &[f64]) -> Vec<f64> {
        w.iter().map(|x| -x).collect() // dF/df = -I
    }
}

fn main() {
    let mut rng = Prng::new(0);

    // ---------- nonlinear adjoint ----------
    let g = 16;
    let n = g * g;
    let f0: Vec<f64> = (0..n).map(|_| 0.5 + rng.uniform()).collect();
    let w = rng.normal_vec(n);
    let factory: rsla::adjoint::nonlinear::ResidualFactory = Rc::new(move |theta: &[f64]| {
        Box::new(QuadPoisson {
            sys: poisson2d(16, None),
            f: theta.to_vec(),
        }) as Box<dyn Residual>
    });

    let tape = Tape::new();
    let theta = tape.leaf_vec(f0.clone());
    let opts = NewtonOpts {
        tol: 1e-13,
        ..Default::default()
    };
    let (u, res) = solve_nonlinear(&tape, factory.clone(), theta, &vec![0.0; n], &opts).unwrap();
    println!(
        "nonlinear: Newton converged in {} iters ({} linear solves), |F| = {:.1e}",
        res.iters, res.linear_solves, res.residual_norm
    );
    let wv = tape.constant_vec(w.clone());
    let loss = tape.dot(u, wv);
    let grads = tape.backward(loss);
    let dtheta = grads.vec(theta).clone();

    let loss_of = |f: &[f64]| {
        let r = (factory)(f);
        let out = newton(r.as_ref(), &vec![0.0; n], &opts);
        assert!(out.converged);
        dot(&out.u, &w)
    };
    let check = rsla::gradcheck::check_direction(loss_of, &f0, &dtheta, 1e-5, 3, 7);
    println!(
        "nonlinear adjoint vs FD: rel error {:.2e}  (paper Table 5: 4.7e-7; bwd = 1 solve)",
        check.rel_error
    );
    assert!(check.rel_error < 1e-5);

    // ---------- eigenvalue adjoint (k = 6, Hellmann–Feynman) ----------
    let a = random_graph_laplacian(&mut rng, 200, 4, 0.5);
    let pattern = Pattern::of(&a);
    let k = 6;
    let tape2 = Tape::new();
    let vals = tape2.leaf_vec(a.vals.clone());
    let eopts = LobpcgOpts {
        tol: 1e-10,
        max_iters: 800,
        seed: 3,
    };
    let (lams, eres) = eigsh(&tape2, &pattern, vals, k, &eopts).unwrap();
    println!(
        "\neigsh: k={k} smallest in {} LOBPCG iters, worst residual {:.1e}",
        eres.iters,
        eres.residuals.iter().cloned().fold(0.0, f64::max)
    );
    let wk: Vec<f64> = (0..k).map(|_| rng.normal()).collect();
    let wkv = tape2.constant_vec(wk.clone());
    let loss2 = tape2.dot(lams, wkv);
    let grads2 = tape2.backward(loss2);
    let dvals = grads2.vec(vals).clone();

    // FD check along a random SYMMETRIC perturbation direction
    let loss_of_vals = |v: &[f64]| {
        let m = pattern.with_vals(v.to_vec());
        let precond = rsla::iterative::Jacobi::new(&m).unwrap();
        let r = rsla::eigen::lobpcg(&m, &precond, k, &eopts);
        r.values.iter().zip(&wk).map(|(l, w)| l * w).sum::<f64>()
    };
    // build symmetric direction: d_ij = d_ji
    let mut dir = vec![0.0; pattern.nnz()];
    let mut rng2 = Prng::new(9);
    for r in 0..pattern.nrows {
        for e in pattern.indptr[r]..pattern.indptr[r + 1] {
            let c = pattern.indices[e];
            if c >= r {
                let v = rng2.normal();
                dir[e] = v;
                if let Some(esym) = pattern.find(c, r) {
                    dir[esym] = v;
                }
            }
        }
    }
    let eps = 1e-6;
    let mut vp = a.vals.clone();
    let mut vm = a.vals.clone();
    for i in 0..dir.len() {
        vp[i] += eps * dir[i];
        vm[i] -= eps * dir[i];
    }
    let fd = (loss_of_vals(&vp) - loss_of_vals(&vm)) / (2.0 * eps);
    let analytic = dot(&dvals, &dir);
    let rel = (analytic - fd).abs() / fd.abs().max(1e-12);
    println!(
        "eigenvalue adjoint vs FD: rel error {:.2e}  (paper Table 5: 2.1e-6; bwd = outer product only)",
        rel
    );
    assert!(rel < 1e-4);
    println!("\nnonlinear_eigen OK");
}
